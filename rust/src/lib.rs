//! # EdgeFaaS
//!
//! A reproduction of *EdgeFaaS: A Function-based Framework for Edge
//! Computing* (Jin & Yang, CS.DC 2022) as a three-layer rust + JAX/Pallas
//! system:
//!
//! * **Layer 3 (this crate)** — the EdgeFaaS coordinator: resource
//!   registration, two-phase function scheduling, virtual function and
//!   virtual storage interfaces, and the unified REST gateway
//!   ([`coordinator`]).
//! * **Layer 2/1 (build-time python)** — the workflows' compute (LeNet-5
//!   training, FedAvg, motion detection, face embedding, k-NN) written in JAX
//!   over Pallas kernels, AOT-lowered to HLO text in `artifacts/` and
//!   executed from rust via the PJRT CPU client ([`runtime`]).
//!
//! Everything the paper's testbed provided is built in-repo as a substrate:
//! the cluster/FaaS backends ([`cluster`]), the object stores ([`objstore`]),
//! monitoring ([`monitor`]), durable mapping backup ([`backup`]), the network
//! ([`simnet`]), and even YAML/JSON/HTTP ([`util`]) since the build
//! environment is offline. See `DESIGN.md` for the substitution table.

pub mod util;
pub mod simnet;
pub mod cluster;
pub mod objstore;
pub mod monitor;
pub mod backup;
pub mod coordinator;
pub mod runtime;
pub mod workflows;
pub mod workloads;
pub mod perfmodel;
pub mod bench_harness;
pub mod testbed;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
