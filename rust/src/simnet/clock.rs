//! Real vs virtual time.
//!
//! All latency-sensitive coordinator code takes a `&dyn Clock` so the same
//! scheduling/placement logic runs under real time in the examples and under
//! virtual time in the figure benches (where the paper's latencies are tens
//! of seconds and must not be slept for real).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotonic clock measured in seconds.
pub trait Clock: Send + Sync {
    /// Seconds since the clock's epoch.
    fn now(&self) -> f64;
    /// Sleep (really or virtually) for `dur` seconds.
    fn sleep(&self, dur: f64);
}

/// Wall-clock time via `std::time::Instant`.
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { start: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn sleep(&self, dur: f64) {
        if dur > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(dur));
        }
    }
}

/// Virtual time: `sleep` advances the clock instantly. Stored as integer
/// nanoseconds in an atomic so concurrent readers need no lock.
///
/// Concurrency semantics (the execution engine's virtual-time mode): a
/// sleeper advances the clock *to* `now + dur` monotonically (`fetch_max`),
/// so concurrent sleeps overlap — four parallel 10 s stage executions end
/// at t=10 s, not t=40 s — while sequential sleeps from one caller still
/// accumulate. This mirrors how parallel function instances on distinct
/// resources overlap on the real testbed.
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { nanos: AtomicU64::new(0) }
    }

    /// Advance the clock to `t` seconds if `t` is ahead (monotonic).
    pub fn advance_to(&self, t: f64) {
        let target = (t * 1e9) as u64;
        self.nanos.fetch_max(target, Ordering::SeqCst);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 / 1e9
    }

    fn sleep(&self, dur: f64) {
        if dur <= 0.0 {
            return;
        }
        let d = (dur * 1e9) as u64;
        // One CAS loop instead of a separate `load` + `fetch_max`: the wake
        // target stays anchored at the value observed on entry (re-anchoring
        // on retry would serialize concurrent sleeps and break the overlap
        // semantics above), and the loop exits as soon as the clock is seen
        // at or past the target — whether this sleeper published it or a
        // concurrent sleeper/advancer already did.
        let mut cur = self.nanos.load(Ordering::SeqCst);
        let target = cur.saturating_add(d);
        while cur < target {
            match self.nanos.compare_exchange_weak(
                cur,
                target,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        c.sleep(0.005);
        let b = c.now();
        assert!(b >= a + 0.004, "a={a} b={b}");
    }

    #[test]
    fn virtual_clock_sleep_is_instant() {
        let c = VirtualClock::new();
        let wall = Instant::now();
        c.sleep(100.0); // "100 seconds"
        assert!(wall.elapsed().as_millis() < 50);
        assert!((c.now() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn virtual_advance_is_monotonic() {
        let c = VirtualClock::new();
        c.advance_to(5.0);
        c.advance_to(3.0); // ignored: behind
        assert!((c.now() - 5.0).abs() < 1e-6);
        c.advance_to(7.5);
        assert!((c.now() - 7.5).abs() < 1e-6);
    }

    #[test]
    fn sequential_sleeps_accumulate() {
        let c = VirtualClock::new();
        c.sleep(2.0);
        c.sleep(3.0);
        assert!((c.now() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn concurrent_sleeps_overlap() {
        // Two sleepers that both observed t=0 advance to max(d1, d2), the
        // way two parallel stage executions on distinct resources would.
        let c = std::sync::Arc::new(VirtualClock::new());
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(2));
        let mut handles = Vec::new();
        for d in [10.0f64, 4.0] {
            let c = std::sync::Arc::clone(&c);
            let b = std::sync::Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                b.wait(); // both read now=0 before either advances
                c.sleep(d);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let t = c.now();
        assert!(t <= 14.0 + 1e-6, "overlapping sleeps must not fully serialize: {t}");
        assert!(t >= 10.0 - 1e-6, "the longest sleep bounds the end time: {t}");
    }

    /// The ISSUE's atomicity property, under real contention: 4 sleeper
    /// threads each run 200 sequential 1 ms sleeps while 4 advancer threads
    /// hammer `advance_to` with a value below every sleeper's accumulated
    /// floor. Invariants:
    ///
    /// * per-sleep progress — after `sleep(d)` returns, `now() >=
    ///   entry_now + d` (a lost update here is what a racy read-modify-write
    ///   pair would produce);
    /// * sequential accumulation — the final time is at least one thread's
    ///   full sleep sum, advancers notwithstanding;
    /// * overlap ceiling — the final time never exceeds the sum of *all*
    ///   sleeps (concurrent sleeps may overlap, never serialize past it).
    #[test]
    fn sleep_invariants_hold_under_8_racing_threads() {
        let c = std::sync::Arc::new(VirtualClock::new());
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(8));
        let per_thread = 200u32;
        let d = 0.001f64; // 1 ms per sleep, exact in integer nanoseconds
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = std::sync::Arc::clone(&c);
            let b = std::sync::Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                b.wait();
                for k in 0..per_thread {
                    let t0 = c.now();
                    c.sleep(d);
                    let t1 = c.now();
                    assert!(
                        t1 >= t0 + d - 1e-9,
                        "sleeper {t} iteration {k}: sleep lost an update (t0={t0} t1={t1})"
                    );
                }
            }));
        }
        for _ in 0..4 {
            let c = std::sync::Arc::clone(&c);
            let b = std::sync::Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                b.wait();
                for _ in 0..per_thread {
                    // Always below the 0.2 s per-thread floor: a correct
                    // sleep must out-accumulate these no matter how the
                    // advancer interleaves with its read-modify-write.
                    c.advance_to(0.05);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let end = c.now();
        let one_thread = per_thread as f64 * d;
        assert!(end >= one_thread - 1e-9, "sequential accumulation under-advanced: {end}");
        assert!(end <= 4.0 * one_thread + 1e-6, "concurrent sleeps serialized: {end}");
    }
}
