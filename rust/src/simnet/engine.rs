//! Discrete-event simulation engine.
//!
//! Drives the virtual-time workflow simulations behind Figs. 8 and 9: stage
//! executions and data transfers are events on a priority queue keyed by
//! virtual time. The engine is deliberately small — events are boxed
//! closures that may schedule further events — but it is enough to model the
//! paper's pipelines, including parallel fan-out (multiple cameras / FL
//! workers) and fan-in barriers (FedAvg aggregation).

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::rc::Rc;

type Event<'a> = Box<dyn FnOnce(&mut SimEngine<'a>) + 'a>;

/// Ordered key: (time in ns, sequence number for FIFO tie-breaking).
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Key(u64, u64);

/// A discrete-event engine with virtual time in seconds.
pub struct SimEngine<'a> {
    now: f64,
    seq: u64,
    queue: BinaryHeap<(Reverse<Key>, usize)>,
    /// Slab of pending events (heap stores indices to keep ordering cheap).
    events: Vec<Option<Event<'a>>>,
}

impl<'a> SimEngine<'a> {
    pub fn new() -> Self {
        SimEngine { now: 0.0, seq: 0, queue: BinaryHeap::new(), events: Vec::new() }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `f` to run `delay` seconds from now.
    pub fn schedule<F: FnOnce(&mut SimEngine<'a>) + 'a>(&mut self, delay: f64, f: F) {
        assert!(delay >= 0.0 && delay.is_finite(), "bad delay {delay}");
        let t = ((self.now + delay) * 1e9).round() as u64;
        let idx = self.events.len();
        self.events.push(Some(Box::new(f)));
        self.queue.push((Reverse(Key(t, self.seq)), idx));
        self.seq += 1;
    }

    /// Run events until the queue is empty; returns the final virtual time.
    pub fn run(&mut self) -> f64 {
        while let Some((Reverse(Key(t, _)), idx)) = self.queue.pop() {
            self.now = t as f64 / 1e9;
            let ev = self.events[idx].take().expect("event fired twice");
            ev(self);
        }
        self.now
    }
}

impl<'a> Default for SimEngine<'a> {
    fn default() -> Self {
        Self::new()
    }
}

/// A fan-in barrier: fires `on_done(engine, t)` once `n` arms have completed,
/// at the time of the last arrival. Used for FedAvg aggregation and
/// multi-camera joins.
pub struct Barrier<'a> {
    remaining: usize,
    on_done: Option<Box<dyn FnOnce(&mut SimEngine<'a>) + 'a>>,
}

impl<'a> Barrier<'a> {
    pub fn new(
        n: usize,
        on_done: impl FnOnce(&mut SimEngine<'a>) + 'a,
    ) -> Rc<RefCell<Barrier<'a>>> {
        assert!(n > 0);
        Rc::new(RefCell::new(Barrier { remaining: n, on_done: Some(Box::new(on_done)) }))
    }

    /// Signal one arm's completion.
    pub fn arrive(this: &Rc<RefCell<Barrier<'a>>>, engine: &mut SimEngine<'a>) {
        let done = {
            let mut b = this.borrow_mut();
            assert!(b.remaining > 0, "barrier over-arrived");
            b.remaining -= 1;
            if b.remaining == 0 {
                b.on_done.take()
            } else {
                None
            }
        };
        if let Some(f) = done {
            f(engine);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut eng = SimEngine::new();
        for (delay, tag) in [(3.0, 'c'), (1.0, 'a'), (2.0, 'b')] {
            let o = Rc::clone(&order);
            eng.schedule(delay, move |e| {
                o.borrow_mut().push((tag, e.now()));
            });
        }
        let end = eng.run();
        assert!((end - 3.0).abs() < 1e-9);
        let o = order.borrow();
        assert_eq!(o.iter().map(|(t, _)| *t).collect::<String>(), "abc");
        assert!((o[0].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ties_fire_fifo() {
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut eng = SimEngine::new();
        for i in 0..5 {
            let o = Rc::clone(&order);
            eng.schedule(1.0, move |_| o.borrow_mut().push(i));
        }
        eng.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn chained_scheduling() {
        // A 3-stage pipeline: each stage takes 2s.
        let end_time = Rc::new(RefCell::new(0.0));
        let mut eng = SimEngine::new();
        let et = Rc::clone(&end_time);
        eng.schedule(2.0, move |e| {
            let et2 = Rc::clone(&et);
            e.schedule(2.0, move |e| {
                let et3 = Rc::clone(&et2);
                e.schedule(2.0, move |e| {
                    *et3.borrow_mut() = e.now();
                });
            });
        });
        eng.run();
        assert!((*end_time.borrow() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn barrier_fires_at_last_arrival() {
        let fired_at = Rc::new(RefCell::new(-1.0));
        let mut eng = SimEngine::new();
        let fa = Rc::clone(&fired_at);
        let barrier = Barrier::new(3, move |e: &mut SimEngine| {
            *fa.borrow_mut() = e.now();
        });
        for delay in [1.0, 5.0, 3.0] {
            let b = Rc::clone(&barrier);
            eng.schedule(delay, move |e| Barrier::arrive(&b, e));
        }
        eng.run();
        assert!((*fired_at.borrow() - 5.0).abs() < 1e-9, "barrier at last arm");
    }

    #[test]
    fn parallel_arms_overlap() {
        // 4 parallel workers of 10s each => total 10s, not 40s.
        let mut eng = SimEngine::new();
        let done = Rc::new(RefCell::new(0));
        for _ in 0..4 {
            let d = Rc::clone(&done);
            eng.schedule(10.0, move |_| *d.borrow_mut() += 1);
        }
        let end = eng.run();
        assert_eq!(*done.borrow(), 4);
        assert!((end - 10.0).abs() < 1e-9);
    }
}
