//! Network topology graph.
//!
//! Nodes are *resources* in the paper's sense (a faasd Raspberry Pi, an edge
//! Kubernetes cluster, the cloud cluster). Links carry an RTT and a
//! bandwidth. Indirect pairs are routed over the minimum-latency path and the
//! path's bandwidth is the bottleneck link (standard fluid model).

use std::collections::BinaryHeap;

/// Index of a node within a [`Topology`].
pub type NodeId = usize;

/// The paper's three resource tiers (Table 3 / Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    Iot,
    Edge,
    Cloud,
}

impl Tier {
    pub fn parse(s: &str) -> anyhow::Result<Tier> {
        match s.to_ascii_lowercase().as_str() {
            "iot" => Ok(Tier::Iot),
            "edge" => Ok(Tier::Edge),
            "cloud" => Ok(Tier::Cloud),
            other => anyhow::bail!("unknown tier `{other}` (expected iot|edge|cloud)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Tier::Iot => "iot",
            Tier::Edge => "edge",
            Tier::Cloud => "cloud",
        }
    }
}

/// A network node.
#[derive(Debug, Clone)]
pub struct NetNode {
    pub name: String,
    pub tier: Tier,
}

/// A bidirectional link.
#[derive(Debug, Clone, Copy)]
pub struct LinkSpec {
    pub a: NodeId,
    pub b: NodeId,
    /// Round-trip time in seconds.
    pub rtt: f64,
    /// Bandwidth in bytes/second.
    pub bw: f64,
}

/// A weighted network graph.
#[derive(Debug, Clone, Default)]
pub struct Topology {
    nodes: Vec<NetNode>,
    links: Vec<LinkSpec>,
    /// adjacency[n] = (neighbor, link index)
    adj: Vec<Vec<(NodeId, usize)>>,
}

/// A routed path between two nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct Route {
    /// Sum of one-way latencies (RTT/2 per hop) in seconds.
    pub latency: f64,
    /// Bottleneck bandwidth along the path, bytes/second.
    pub bw: f64,
    /// Node sequence including both endpoints.
    pub hops: Vec<NodeId>,
}

impl Topology {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_node(&mut self, name: impl Into<String>, tier: Tier) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(NetNode { name: name.into(), tier });
        self.adj.push(Vec::new());
        id
    }

    pub fn add_link(&mut self, a: NodeId, b: NodeId, rtt: f64, bw: f64) {
        assert!(a < self.nodes.len() && b < self.nodes.len(), "bad link endpoints");
        assert!(rtt >= 0.0 && bw > 0.0, "bad link parameters");
        let idx = self.links.len();
        self.links.push(LinkSpec { a, b, rtt, bw });
        self.adj[a].push((b, idx));
        self.adj[b].push((a, idx));
    }

    pub fn node(&self, id: NodeId) -> &NetNode {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn nodes(&self) -> impl Iterator<Item = (NodeId, &NetNode)> {
        self.nodes.iter().enumerate()
    }

    pub fn by_name(&self, name: &str) -> Option<NodeId> {
        self.nodes.iter().position(|n| n.name == name)
    }

    pub fn tier_nodes(&self, tier: Tier) -> Vec<NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.tier == tier)
            .map(|(i, _)| i)
            .collect()
    }

    /// Minimum-latency route between two nodes (Dijkstra on one-way latency).
    /// Returns `None` if disconnected. `from == to` yields a zero-latency,
    /// infinite-bandwidth loopback route.
    pub fn route(&self, from: NodeId, to: NodeId) -> Option<Route> {
        if from == to {
            return Some(Route { latency: 0.0, bw: f64::INFINITY, hops: vec![from] });
        }
        #[derive(PartialEq)]
        struct Item(f64, NodeId);
        impl Eq for Item {}
        impl PartialOrd for Item {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Item {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                // Min-heap on latency (total_cmp: NaN-safe total order).
                o.0.total_cmp(&self.0)
            }
        }
        let n = self.nodes.len();
        let mut dist = vec![f64::INFINITY; n];
        let mut prev: Vec<Option<(NodeId, usize)>> = vec![None; n];
        let mut heap = BinaryHeap::new();
        dist[from] = 0.0;
        heap.push(Item(0.0, from));
        while let Some(Item(d, u)) = heap.pop() {
            if d > dist[u] {
                continue;
            }
            if u == to {
                break;
            }
            for &(v, li) in &self.adj[u] {
                let nd = d + self.links[li].rtt / 2.0;
                if nd < dist[v] {
                    dist[v] = nd;
                    prev[v] = Some((u, li));
                    heap.push(Item(nd, v));
                }
            }
        }
        if dist[to].is_infinite() {
            return None;
        }
        // Reconstruct and find bottleneck bandwidth.
        let mut hops = vec![to];
        let mut bw = f64::INFINITY;
        let mut cur = to;
        while let Some((p, li)) = prev[cur] {
            bw = bw.min(self.links[li].bw);
            hops.push(p);
            cur = p;
        }
        hops.reverse();
        Some(Route { latency: dist[to], bw, hops })
    }

    /// One-way latency between nodes in seconds (`INFINITY` if disconnected).
    pub fn latency(&self, from: NodeId, to: NodeId) -> f64 {
        self.route(from, to).map(|r| r.latency).unwrap_or(f64::INFINITY)
    }

    /// One-way latency from `from` to *every* node, in node order
    /// (`INFINITY` for unreachable nodes; `0.0` at `from` itself). One
    /// Dijkstra pass over the whole graph — the building block of the
    /// monitoring plane's dense latency matrix
    /// ([`crate::monitor::snapshot::LatencyMatrix`]), which needs all-pairs
    /// distances without paying a per-pair shortest-path search.
    pub fn latencies_from(&self, from: NodeId) -> Vec<f64> {
        let n = self.nodes.len();
        let mut dist = vec![f64::INFINITY; n];
        if from >= n {
            return dist;
        }
        #[derive(PartialEq)]
        struct Item(f64, NodeId);
        impl Eq for Item {}
        impl PartialOrd for Item {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Item {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                o.0.total_cmp(&self.0)
            }
        }
        let mut heap = BinaryHeap::new();
        dist[from] = 0.0;
        heap.push(Item(0.0, from));
        while let Some(Item(d, u)) = heap.pop() {
            if d > dist[u] {
                continue;
            }
            for &(v, li) in &self.adj[u] {
                let nd = d + self.links[li].rtt / 2.0;
                if nd < dist[v] {
                    dist[v] = nd;
                    heap.push(Item(nd, v));
                }
            }
        }
        dist
    }

    /// The node of `tier` with minimum latency from `from` (NaN-safe:
    /// `total_cmp` sorts NaN distances last instead of tying).
    pub fn closest(&self, from: NodeId, tier: Tier) -> Option<NodeId> {
        self.tier_nodes(tier)
            .into_iter()
            .min_by(|&a, &b| self.latency(from, a).total_cmp(&self.latency(from, b)))
    }

    /// The node of `tier` minimizing the *sum* of latencies from all `froms`
    /// (used by `reduce: 1` fan-in placement).
    pub fn closest_to_all(&self, froms: &[NodeId], tier: Tier) -> Option<NodeId> {
        self.tier_nodes(tier).into_iter().min_by(|&a, &b| {
            let sa: f64 = froms.iter().map(|&f| self.latency(f, a)).sum();
            let sb: f64 = froms.iter().map(|&f| self.latency(f, b)).sum();
            sa.total_cmp(&sb)
        })
    }
}

/// Megabits/second to bytes/second.
pub fn mbps(v: f64) -> f64 {
    v * 1e6 / 8.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line3() -> (Topology, NodeId, NodeId, NodeId) {
        // iot --(1ms, 100MB/s)-- edge --(10ms, 10MB/s)-- cloud
        let mut t = Topology::new();
        let i = t.add_node("pi", Tier::Iot);
        let e = t.add_node("edge", Tier::Edge);
        let c = t.add_node("cloud", Tier::Cloud);
        t.add_link(i, e, 0.001, 100e6);
        t.add_link(e, c, 0.010, 10e6);
        (t, i, e, c)
    }

    #[test]
    fn direct_route() {
        let (t, i, e, _) = line3();
        let r = t.route(i, e).unwrap();
        assert!((r.latency - 0.0005).abs() < 1e-12);
        assert_eq!(r.bw, 100e6);
        assert_eq!(r.hops, vec![i, e]);
    }

    #[test]
    fn multi_hop_route_bottleneck() {
        let (t, i, _, c) = line3();
        let r = t.route(i, c).unwrap();
        assert!((r.latency - 0.0055).abs() < 1e-12);
        assert_eq!(r.bw, 10e6, "bottleneck is the WAN link");
        assert_eq!(r.hops.len(), 3);
    }

    #[test]
    fn loopback_route() {
        let (t, i, _, _) = line3();
        let r = t.route(i, i).unwrap();
        assert_eq!(r.latency, 0.0);
        assert!(r.bw.is_infinite());
    }

    #[test]
    fn disconnected_is_none() {
        let mut t = Topology::new();
        let a = t.add_node("a", Tier::Iot);
        let b = t.add_node("b", Tier::Cloud);
        assert!(t.route(a, b).is_none());
        assert!(t.latency(a, b).is_infinite());
    }

    #[test]
    fn closest_picks_lower_latency() {
        let mut t = Topology::new();
        let i = t.add_node("pi", Tier::Iot);
        let e1 = t.add_node("edge1", Tier::Edge);
        let e2 = t.add_node("edge2", Tier::Edge);
        t.add_link(i, e1, 0.0057, mbps(100.0));
        t.add_link(i, e2, 0.050, mbps(100.0));
        assert_eq!(t.closest(i, Tier::Edge), Some(e1));
    }

    #[test]
    fn closest_to_all_minimizes_sum() {
        let mut t = Topology::new();
        let a = t.add_node("a", Tier::Iot);
        let b = t.add_node("b", Tier::Iot);
        let c1 = t.add_node("c1", Tier::Cloud);
        let c2 = t.add_node("c2", Tier::Cloud);
        t.add_link(a, c1, 0.010, mbps(10.0));
        t.add_link(b, c1, 0.010, mbps(10.0));
        t.add_link(a, c2, 0.001, mbps(10.0));
        t.add_link(b, c2, 0.100, mbps(10.0));
        // c1: 5ms+5ms = 10ms; c2: 0.5ms+50ms = 50.5ms → pick c1.
        assert_eq!(t.closest_to_all(&[a, b], Tier::Cloud), Some(c1));
    }

    #[test]
    fn dijkstra_prefers_low_latency_path() {
        let mut t = Topology::new();
        let a = t.add_node("a", Tier::Iot);
        let m = t.add_node("m", Tier::Edge);
        let b = t.add_node("b", Tier::Cloud);
        t.add_link(a, b, 0.100, mbps(1000.0)); // direct but slow
        t.add_link(a, m, 0.010, mbps(10.0));
        t.add_link(m, b, 0.010, mbps(10.0));
        let r = t.route(a, b).unwrap();
        assert_eq!(r.hops, vec![a, m, b], "two fast hops beat one slow hop");
        assert_eq!(r.bw, mbps(10.0));
    }

    #[test]
    fn latencies_from_matches_per_pair_routes() {
        let (t, i, e, c) = line3();
        let d = t.latencies_from(i);
        for to in [i, e, c] {
            assert!(
                (d[to] - t.latency(i, to)).abs() < 1e-12,
                "single-sweep distance to {to} diverges from route()"
            );
        }
        assert_eq!(d[i], 0.0);
        // Disconnected and out-of-range nodes are INFINITY.
        let mut t2 = Topology::new();
        let a = t2.add_node("a", Tier::Iot);
        let b = t2.add_node("b", Tier::Cloud);
        assert!(t2.latencies_from(a)[b].is_infinite());
        assert!(t2.latencies_from(99).iter().all(|d| d.is_infinite()));
    }

    #[test]
    fn tier_parse() {
        assert_eq!(Tier::parse("IoT").unwrap(), Tier::Iot);
        assert_eq!(Tier::parse("edge").unwrap(), Tier::Edge);
        assert!(Tier::parse("fog").is_err());
    }
}
