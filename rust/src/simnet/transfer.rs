//! Transfer-time model.
//!
//! The paper's Fig. 6 shows the output-upload latency of each video stage to
//! the edge vs cloud tier; the dominant term is `bytes / bandwidth` (92 MB at
//! 7.39 Mbps ≈ 92.7 s to cloud). We model a transfer as
//!
//! ```text
//! time = route.latency                (one-way propagation)
//!      + per_request_overhead         (HTTP + object-store bookkeeping)
//!      + bytes / route.bw             (serialization at the bottleneck)
//! ```
//!
//! which is the standard fluid approximation and is exact in the paper's
//! regime (single flow, large transfers).

use super::topology::{NodeId, Topology};

/// Transfer cost model over a [`Topology`].
#[derive(Debug, Clone)]
pub struct TransferModel {
    /// Fixed per-request overhead in seconds (connection setup, object-store
    /// metadata). Calibrated small relative to Fig. 6's numbers.
    pub per_request_overhead: f64,
}

impl Default for TransferModel {
    fn default() -> Self {
        TransferModel { per_request_overhead: 0.010 }
    }
}

impl TransferModel {
    /// Time in seconds to move `bytes` from `from` to `to`.
    /// Local (same-node) transfers cost only the request overhead — the
    /// paper's data-locality argument in one line.
    pub fn time(&self, topo: &Topology, from: NodeId, to: NodeId, bytes: u64) -> f64 {
        if from == to {
            return self.per_request_overhead;
        }
        let route = match topo.route(from, to) {
            Some(r) => r,
            None => return f64::INFINITY,
        };
        route.latency + self.per_request_overhead + bytes as f64 / route.bw
    }

    /// Effective throughput in bytes/second for a transfer of `bytes`.
    pub fn throughput(&self, topo: &Topology, from: NodeId, to: NodeId, bytes: u64) -> f64 {
        let t = self.time(topo, from, to, bytes);
        if t.is_finite() && t > 0.0 {
            bytes as f64 / t
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::topology::{mbps, Tier};

    #[test]
    fn local_transfer_is_overhead_only() {
        let mut topo = Topology::new();
        let a = topo.add_node("a", Tier::Iot);
        let m = TransferModel::default();
        assert!((m.time(&topo, a, a, 1 << 30) - m.per_request_overhead).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let mut topo = Topology::new();
        let a = topo.add_node("a", Tier::Iot);
        let b = topo.add_node("b", Tier::Cloud);
        topo.add_link(a, b, 0.0434, mbps(7.94));
        let m = TransferModel::default();
        // 92 MB (decimal, as the paper reports sizes) at ~7.94 Mbps ≈ 92.7 s
        // — the paper's Fig. 6 headline number.
        let t = m.time(&topo, a, b, 92_000_000);
        assert!((t - 92.7).abs() < 2.0, "t={t}");
    }

    #[test]
    fn disconnected_is_infinite() {
        let mut topo = Topology::new();
        let a = topo.add_node("a", Tier::Iot);
        let b = topo.add_node("b", Tier::Cloud);
        let m = TransferModel::default();
        assert!(m.time(&topo, a, b, 1).is_infinite());
        assert_eq!(m.throughput(&topo, a, b, 1), 0.0);
    }

    #[test]
    fn monotonic_in_size() {
        let mut topo = Topology::new();
        let a = topo.add_node("a", Tier::Iot);
        let b = topo.add_node("b", Tier::Edge);
        topo.add_link(a, b, 0.001, mbps(100.0));
        let m = TransferModel::default();
        let mut prev = 0.0;
        for mb in [1u64, 10, 50, 92] {
            let t = m.time(&topo, a, b, mb << 20);
            assert!(t > prev);
            prev = t;
        }
    }
}
