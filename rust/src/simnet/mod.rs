//! Network + time substrate.
//!
//! The paper's evaluation runs on a physical testbed (Fig. 4): two sets of
//! {4 Raspberry Pis + 1 edge server} plus a remote cloud cluster, with
//! measured RTTs (5.7 ms / 43.4 ms and 0.6 ms / 4.7 ms) and a ~7-8 Mbps
//! uplink from the IoT LAN to the cloud. We replace the physical network
//! with:
//!
//! * [`topology`] — a weighted graph of nodes and links with per-link RTT and
//!   bandwidth, plus latency-routing (Dijkstra) for indirect pairs;
//! * [`transfer`] — the transfer-time model `rtt + bytes / bottleneck_bw`
//!   calibrated so that the paper's Fig. 6 numbers are reproduced;
//! * [`clock`] — a `Clock` abstraction so that the same coordinator code —
//!   including the event-driven execution engine in
//!   `crate::coordinator::engine` — runs in real time (examples, loopback
//!   HTTP) or virtual time (benches), with concurrent virtual sleeps
//!   overlapping the way parallel stage executions do on real hardware;
//! * [`simclock`] — true discrete-event virtual time behind the same
//!   `Clock` trait: sleepers register wake events on an event wheel and a
//!   driver thread advances time only when every live actor is parked, so
//!   populations of thousands of paced submitters simulate hours in wall
//!   seconds (the scale harness, `workloads::population`, runs on it);
//! * [`engine`] — a discrete-event engine used by the workflow simulations
//!   (Figs. 8/9) so a 96.7 s cloud-only pipeline simulates in microseconds.

pub mod clock;
pub mod engine;
pub mod simclock;
pub mod topology;
pub mod transfer;

pub use clock::{Clock, RealClock, VirtualClock};
pub use engine::SimEngine;
pub use simclock::{SimActor, SimClock};
pub use topology::{LinkSpec, NodeId, Tier, Topology};
pub use transfer::TransferModel;
