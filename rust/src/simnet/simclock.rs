//! True discrete-event virtual time.
//!
//! [`VirtualClock`](super::VirtualClock) is a monotonic `fetch_max`
//! counter: a sleeper advances the clock itself, instantly, which is fine
//! when every sleeper is also the only actor (the figure benches) but
//! cannot coordinate a *population* — thousands of paced submitters plus
//! engine workers — because whichever thread sleeps first drags the clock
//! forward under everyone else's feet.
//!
//! [`SimClock`] is a discrete-event scheduler behind the same
//! [`Clock`] trait:
//!
//! * every `sleep(d)` registers a wake event at `now + d` on an **event
//!   wheel** (a `BTreeSet` keyed by `(wake_ns, ticket)`) and parks the OS
//!   thread on a condvar;
//! * a **driver thread** advances virtual time to the earliest pending
//!   wake point — but only when every *registered actor* (see
//!   [`SimClock::actor`]) is parked and no already-due sleeper has yet to
//!   exit — then broadcasts, wakes the due sleepers, and waits for the
//!   wheel to quiesce again;
//! * `now()` is a lock-free atomic read, so hot-path engine code pays the
//!   same cost as under `VirtualClock`.
//!
//! Actor registration is what makes pacing sound: a workload generator
//! takes a [`SimActor`] guard and paces its submissions with
//! [`SimActor::sleep`]; the driver will not advance past the generator's
//! next arrival while it is mid-submission (registered, not parked).
//! Threads that sleep through the plain [`Clock`] interface — engine
//! backends simulating service time — park *passively*: they gate
//! advancement only while their event is due, so a million device-sleeps
//! cost one `BTreeSet` insert + one condvar park each, and virtual hours
//! simulate in wall seconds.
//!
//! Dropping the last reference shuts the driver down and releases any
//! still-parked sleepers (their remaining virtual delay is abandoned —
//! only relevant on teardown).

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::clock::Clock;

#[derive(Default)]
struct State {
    /// Pending wake points, one `(wake_ns, ticket)` entry per parked
    /// sleeper; the ticket disambiguates equal wake times.
    wheel: BTreeSet<(u64, u64)>,
    next_ticket: u64,
    /// Live [`SimActor`] guards.
    actors: usize,
    /// Registered actors currently parked in a sleep.
    actors_parked: usize,
    shutdown: bool,
}

struct Core {
    state: Mutex<State>,
    /// Sleepers park here; time advances are broadcast on it.
    wake_cv: Condvar,
    /// The driver parks here; sleep entry/exit, actor release, and
    /// shutdown all signal it.
    driver_cv: Condvar,
    /// Mirror of the current virtual time for lock-free `now()`. Written
    /// only under the state mutex, so stores are totally ordered.
    now_ns: AtomicU64,
}

impl Core {
    /// Register a wake event and park until virtual time reaches it.
    /// `registered` marks the parked interval as an actor's (it then
    /// counts toward the driver's all-actors-parked gate).
    fn park(&self, dur_s: f64, registered: bool) {
        if dur_s <= 0.0 {
            return;
        }
        // Ceil so no positive sleep rounds to a zero-length event.
        let d = ((dur_s * 1e9).ceil() as u64).max(1);
        let mut st = self.state.lock().unwrap();
        let wake = self.now_ns.load(Ordering::SeqCst).saturating_add(d);
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.wheel.insert((wake, ticket));
        if registered {
            st.actors_parked += 1;
        }
        self.driver_cv.notify_one();
        while self.now_ns.load(Ordering::SeqCst) < wake && !st.shutdown {
            st = self.wake_cv.wait(st).unwrap();
        }
        st.wheel.remove(&(wake, ticket));
        if registered {
            st.actors_parked -= 1;
        }
        // Exit may unblock the driver: either the last due sleeper left
        // the wheel, or the last registered actor just re-parked elsewhere.
        self.driver_cv.notify_one();
    }
}

/// The event-wheel driver: advance to the earliest wake point exactly when
/// the system is quiescent — every registered actor parked, and no sleeper
/// whose wake time has already been reached still on the wheel (it was
/// woken but has not yet exited `park`).
fn drive(core: &Core) {
    let mut st = core.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        let now = core.now_ns.load(Ordering::SeqCst);
        if let Some(&(wake, _)) = st.wheel.first() {
            if wake > now && st.actors_parked >= st.actors {
                core.now_ns.store(wake, Ordering::SeqCst);
                core.wake_cv.notify_all();
                // Fall through to wait: the entries at `wake` are now due
                // and must exit before the next advance. Their exits (and
                // any new sleeps) signal `driver_cv`; the mutex is held
                // from this store through the wait, so no signal is lost.
            }
        }
        st = core.driver_cv.wait(st).unwrap();
    }
}

/// A discrete-event virtual clock. See the module docs; construct with
/// [`SimClock::new`], share as `Arc<dyn Clock>`, register pacing threads
/// via [`SimClock::actor`].
pub struct SimClock {
    core: Arc<Core>,
    driver: Mutex<Option<JoinHandle<()>>>,
}

impl SimClock {
    pub fn new() -> Self {
        let core = Arc::new(Core {
            state: Mutex::new(State::default()),
            wake_cv: Condvar::new(),
            driver_cv: Condvar::new(),
            now_ns: AtomicU64::new(0),
        });
        let driver_core = Arc::clone(&core);
        let driver = std::thread::Builder::new()
            .name("simclock-driver".into())
            .spawn(move || drive(&driver_core))
            .expect("spawn simclock driver");
        SimClock { core, driver: Mutex::new(Some(driver)) }
    }

    /// Register a live actor. While the returned guard exists and is not
    /// inside [`SimActor::sleep`], the driver will not advance virtual
    /// time — the actor is presumed busy scheduling work for "now".
    /// Dropping (or [`SimActor::release`]-ing) the guard lets time
    /// free-run past the actor again.
    pub fn actor(self: &Arc<Self>) -> SimActor {
        self.core.state.lock().unwrap().actors += 1;
        SimActor { core: Arc::clone(&self.core), released: AtomicBool::new(false) }
    }

    /// Number of wake events currently on the wheel (tests use this to
    /// handshake with sleepers deterministically).
    #[cfg(test)]
    fn pending_events(&self) -> usize {
        self.core.state.lock().unwrap().wheel.len()
    }

    /// Advance the clock to `t` seconds if `t` is ahead (monotonic) and
    /// wake every sleeper whose wake point is now due. Mirrors
    /// [`VirtualClock::advance_to`](super::VirtualClock::advance_to).
    pub fn advance_to(&self, t: f64) {
        let target = (t * 1e9) as u64;
        let st = self.core.state.lock().unwrap();
        if target > self.core.now_ns.load(Ordering::SeqCst) {
            self.core.now_ns.store(target, Ordering::SeqCst);
            self.core.wake_cv.notify_all();
            self.core.driver_cv.notify_one();
        }
        drop(st);
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        self.core.now_ns.load(Ordering::SeqCst) as f64 / 1e9
    }

    /// Passive (unregistered) sleep: park on the wheel until the driver —
    /// or an `advance_to` — reaches the wake point.
    fn sleep(&self, dur: f64) {
        self.core.park(dur, false);
    }
}

impl Drop for SimClock {
    fn drop(&mut self) {
        {
            let mut st = self.core.state.lock().unwrap();
            st.shutdown = true;
        }
        self.core.wake_cv.notify_all();
        self.core.driver_cv.notify_all();
        if let Some(h) = self.driver.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Registered-actor guard from [`SimClock::actor`]. The guard's
/// [`sleep`](SimActor::sleep) is the *pacing* sleep: virtual time cannot
/// run ahead of a live actor that is not parked in one.
pub struct SimActor {
    core: Arc<Core>,
    released: AtomicBool,
}

impl SimActor {
    /// Park this actor for `dur_s` virtual seconds. Unlike the passive
    /// [`Clock::sleep`], the parked interval counts toward the driver's
    /// all-actors-parked gate, so the wake fires at exactly `now + dur_s`
    /// — no other thread can drag time past it first.
    pub fn sleep(&self, dur_s: f64) {
        debug_assert!(!self.released.load(Ordering::SeqCst), "sleep on a released SimActor");
        self.core.park(dur_s, true);
    }

    /// Deregister the actor (idempotent; also runs on drop). After
    /// release, the driver free-runs the remaining wheel without waiting
    /// on this actor — call it after a generator's last submission so
    /// in-flight service-time sleeps can drain at full speed.
    pub fn release(&self) {
        if !self.released.swap(true, Ordering::SeqCst) {
            self.core.state.lock().unwrap().actors -= 1;
            self.core.driver_cv.notify_one();
        }
    }
}

impl Drop for SimActor {
    fn drop(&mut self) {
        self.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn unregistered_sleep_advances_in_wall_microseconds() {
        let c = Arc::new(SimClock::new());
        let wall = Instant::now();
        c.sleep(3600.0); // "one virtual hour"
        assert!(wall.elapsed().as_millis() < 500);
        assert!((c.now() - 3600.0).abs() < 1e-6);
    }

    #[test]
    fn events_fire_in_time_order_not_spawn_order() {
        let c = Arc::new(SimClock::new());
        // Pin an actor so only the explicit advance_to steps move time:
        // each step must wake exactly the sleepers whose wake point is due,
        // regardless of spawn order (spawned long-first here).
        let pin = c.actor();
        let done: Arc<Vec<AtomicBool>> =
            Arc::new((0..3).map(|_| AtomicBool::new(false)).collect());
        let mut handles = Vec::new();
        for (i, d) in [30.0f64, 20.0, 10.0].into_iter().enumerate() {
            let c = Arc::clone(&c);
            let done = Arc::clone(&done);
            handles.push(std::thread::spawn(move || {
                c.sleep(d);
                done[i].store(true, Ordering::SeqCst);
            }));
        }
        while c.pending_events() < 3 {
            std::thread::yield_now();
        }
        for (step, woken) in [(10.0f64, 2usize), (20.0, 1), (30.0, 0)] {
            c.advance_to(step);
            while !done[woken].load(Ordering::SeqCst) {
                std::thread::yield_now();
            }
            for (i, flag) in done.iter().enumerate() {
                let expect = [30.0, 20.0, 10.0][i] <= step + 1e-9;
                assert_eq!(
                    flag.load(Ordering::SeqCst),
                    expect,
                    "after advance_to({step}): sleeper {i} wrong wake state"
                );
            }
        }
        pin.release();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sequential_sleeps_accumulate_and_concurrent_sleeps_overlap() {
        let c = Arc::new(SimClock::new());
        c.sleep(2.0);
        c.sleep(3.0);
        assert!((c.now() - 5.0).abs() < 1e-6, "sequential sleeps accumulate");
        // Two overlapping sleepers, both anchored at t=5 (an actor pin
        // holds time until both events are registered): end at t=5+10.
        let pin = c.actor();
        let mut handles = Vec::new();
        for d in [10.0f64, 4.0] {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                c.sleep(d);
            }));
        }
        while c.pending_events() < 2 {
            std::thread::yield_now();
        }
        pin.release();
        for h in handles {
            h.join().unwrap();
        }
        assert!((c.now() - 15.0).abs() < 1e-6, "concurrent sleeps overlap: {}", c.now());
    }

    #[test]
    fn registered_actor_gates_advancement() {
        let c = Arc::new(SimClock::new());
        let actor = c.actor();
        let done = Arc::new(AtomicBool::new(false));
        let t = {
            let c = Arc::clone(&c);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                c.sleep(100.0); // passive: must NOT advance while the actor is live
                done.store(true, Ordering::SeqCst);
            })
        };
        while c.pending_events() < 1 {
            std::thread::yield_now();
        }
        // The actor is live and unparked: the passive sleeper stays parked.
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!done.load(Ordering::SeqCst), "time advanced past a live actor");
        assert!(c.now() < 1e-9);
        // Actor pacing: a 10 s actor sleep wakes at exactly t=10 (the
        // passive 100 s event stays pending), then release free-runs it.
        actor.sleep(10.0);
        assert!((c.now() - 10.0).abs() < 1e-6, "actor wake is the earliest event");
        assert!(!done.load(Ordering::SeqCst));
        actor.release();
        t.join().unwrap();
        assert!(done.load(Ordering::SeqCst));
        assert!((c.now() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn advance_to_is_monotonic_and_wakes_due_sleepers() {
        let c = Arc::new(SimClock::new());
        let _actor = c.actor(); // pin the driver so only advance_to moves time
        let woke = Arc::new(AtomicBool::new(false));
        let t = {
            let c = Arc::clone(&c);
            let woke = Arc::clone(&woke);
            std::thread::spawn(move || {
                c.sleep(5.0);
                woke.store(true, Ordering::SeqCst);
            })
        };
        while c.pending_events() < 1 {
            std::thread::yield_now();
        }
        assert!(!woke.load(Ordering::SeqCst));
        c.advance_to(3.0);
        c.advance_to(2.0); // ignored: behind
        assert!((c.now() - 3.0).abs() < 1e-6);
        c.advance_to(7.5);
        t.join().unwrap();
        assert!(woke.load(Ordering::SeqCst));
        assert!((c.now() - 7.5).abs() < 1e-6);
    }

    #[test]
    fn drop_joins_the_driver_cleanly() {
        let c = Arc::new(SimClock::new());
        c.sleep(5.0);
        assert!((c.now() - 5.0).abs() < 1e-6);
        drop(c); // must shut down and join the driver thread, not hang
    }
}
