//! Measurement + reporting harness for the paper-figure benches.
//!
//! The offline build has no criterion, so `benches/*` (built with
//! `harness = false`) use this: warmup + timed iterations with min / mean /
//! p50 / p95 / p99 statistics, and an aligned-table printer so every bench
//! emits the same rows/series the paper's figures report. The scale
//! harness (`benches/scale_population.rs`) also feeds *virtual-time*
//! end-to-end latencies through [`Stats::of`] — the statistics are
//! unit-agnostic.

use std::time::Instant;

/// Latency statistics over a set of timed iterations, seconds.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub min: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Stats {
    pub fn of(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len();
        let pick = |q: f64| samples[((n as f64 - 1.0) * q).round() as usize];
        Stats {
            iters: n,
            min: samples[0],
            mean: samples.iter().sum::<f64>() / n as f64,
            p50: pick(0.5),
            p95: pick(0.95),
            p99: pick(0.99),
            max: samples[n - 1],
        }
    }

    /// Human-readable duration.
    pub fn fmt(seconds: f64) -> String {
        if seconds >= 1.0 {
            format!("{seconds:.3} s")
        } else if seconds >= 1e-3 {
            format!("{:.3} ms", seconds * 1e3)
        } else if seconds >= 1e-6 {
            format!("{:.3} µs", seconds * 1e6)
        } else {
            format!("{:.0} ns", seconds * 1e9)
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Stats::of(samples)
}

/// An aligned text table (the benches' figure output format).
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n=== {} ===\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_quantiles() {
        let s = Stats::of((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.0).abs() < 1.5);
        assert!((s.p95 - 95.0).abs() < 1.5);
        assert!((s.p99 - 99.0).abs() < 1.5);
    }

    #[test]
    fn measure_runs_the_closure() {
        let mut count = 0;
        let s = measure(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(Stats::fmt(2.5), "2.500 s");
        assert_eq!(Stats::fmt(0.0025), "2.500 ms");
        assert_eq!(Stats::fmt(2.5e-6), "2.500 µs");
        assert_eq!(Stats::fmt(2.5e-8), "25 ns");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Fig. X", &["stage", "edge (s)", "cloud (s)"]);
        t.row(&["video-generator".into(), "8.5".into(), "92.7".into()]);
        t.row(&["face-recognition".into(), "0.05".into(), "0.5".into()]);
        let s = t.to_string();
        assert!(s.contains("=== Fig. X ==="));
        assert!(s.contains("video-generator"));
        let lines: Vec<&str> =
            s.lines().filter(|l| l.contains("8.5") || l.contains("0.05")).collect();
        assert_eq!(lines.len(), 2);
    }
}
