//! Host tensors crossing the rust <-> PJRT boundary.
//!
//! Function payloads inside EdgeFaaS are tensors (frames, model parameters,
//! embeddings). [`Tensor`] is the host-side representation with a compact,
//! self-describing binary wire format so tensors can travel through the
//! object stores and HTTP gateways unchanged:
//!
//! ```text
//! [magic "EFT1"][dtype u8][rank u8][dims u32 x rank][data little-endian]
//! ```

use anyhow::{bail, Context};

/// Supported element types (the artifact entries only use these two).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype `{other}`"),
        }
    }

    pub fn size(&self) -> usize {
        4
    }
}

/// Tensor payload.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> anyhow::Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {shape:?} needs {n} elements, got {}", data.len());
        }
        Ok(Tensor { shape, data: Data::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> anyhow::Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {shape:?} needs {n} elements, got {}", data.len());
        }
        Ok(Tensor { shape, data: Data::I32(data) })
    }

    /// Scalar f32.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    /// All-zeros f32 tensor.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: Data::F32(vec![0.0; n]) }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => bail!("expected i32 tensor, got f32"),
        }
    }

    /// First element as f32 (scalars).
    pub fn item(&self) -> anyhow::Result<f32> {
        match &self.data {
            Data::F32(v) => v.first().copied().context("empty tensor"),
            Data::I32(v) => v.first().map(|&x| x as f32).context("empty tensor"),
        }
    }

    // ------------------------------------------------------- wire format --

    const MAGIC: &'static [u8; 4] = b"EFT1";

    /// Serialize to the wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(6 + 4 * self.shape.len() + 4 * self.len());
        out.extend_from_slice(Self::MAGIC);
        out.push(match self.dtype() {
            DType::F32 => 0,
            DType::I32 => 1,
        });
        out.push(self.shape.len() as u8);
        for &d in &self.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match &self.data {
            Data::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        out
    }

    /// Deserialize from the wire format.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Tensor> {
        if bytes.len() < 6 || &bytes[..4] != Self::MAGIC {
            bail!("not a tensor payload (bad magic)");
        }
        let dtype = match bytes[4] {
            0 => DType::F32,
            1 => DType::I32,
            other => bail!("bad dtype tag {other}"),
        };
        let rank = bytes[5] as usize;
        let mut off = 6;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            if off + 4 > bytes.len() {
                bail!("truncated tensor header");
            }
            shape.push(u32::from_le_bytes(bytes[off..off + 4].try_into()?) as usize);
            off += 4;
        }
        let n: usize = shape.iter().product();
        if bytes.len() != off + 4 * n {
            bail!("tensor payload size mismatch: want {} data bytes, have {}", 4 * n, bytes.len() - off);
        }
        let data = match dtype {
            DType::F32 => {
                let mut v = Vec::with_capacity(n);
                for i in 0..n {
                    let b = &bytes[off + 4 * i..off + 4 * i + 4];
                    v.push(f32::from_le_bytes(b.try_into()?));
                }
                Data::F32(v)
            }
            DType::I32 => {
                let mut v = Vec::with_capacity(n);
                for i in 0..n {
                    let b = &bytes[off + 4 * i..off + 4 * i + 4];
                    v.push(i32::from_le_bytes(b.try_into()?));
                }
                Data::I32(v)
            }
        };
        Ok(Tensor { shape, data })
    }

    /// Byte length of the serialized form without serializing.
    pub fn wire_len(&self) -> usize {
        6 + 4 * self.shape.len() + 4 * self.len()
    }

    // --------------------------------------------------- XLA conversions --

    /// Convert to an `xla::Literal`.
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let bytes: Vec<u8> = match &self.data {
            Data::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Data::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        };
        let ty = match self.dtype() {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &self.shape, &bytes)
            .map_err(|e| anyhow::anyhow!("literal create: {e}"))
    }

    /// Convert from an `xla::Literal`.
    pub fn from_literal(lit: &xla::Literal) -> anyhow::Result<Tensor> {
        let shape = lit.shape().map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
        let array = match &shape {
            xla::Shape::Array(a) => a,
            other => bail!("expected array literal, got {other:?}"),
        };
        let dims: Vec<usize> = array.dims().iter().map(|&d| d as usize).collect();
        match array.primitive_type() {
            xla::PrimitiveType::F32 => {
                let v = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
                Tensor::f32(dims, v)
            }
            xla::PrimitiveType::S32 => {
                let v = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
                Tensor::i32(dims, v)
            }
            other => bail!("unsupported literal type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_validate_shape() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn wire_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, -2.5, 3.25, 0.0]).unwrap();
        let bytes = t.to_bytes();
        assert_eq!(bytes.len(), t.wire_len());
        assert_eq!(Tensor::from_bytes(&bytes).unwrap(), t);
    }

    #[test]
    fn wire_roundtrip_i32_and_scalar() {
        let t = Tensor::i32(vec![3], vec![-1, 0, 7]).unwrap();
        assert_eq!(Tensor::from_bytes(&t.to_bytes()).unwrap(), t);
        let s = Tensor::scalar(0.5);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(Tensor::from_bytes(&s.to_bytes()).unwrap(), s);
        assert_eq!(s.item().unwrap(), 0.5);
    }

    #[test]
    fn rejects_malformed_payloads() {
        assert!(Tensor::from_bytes(b"nope").is_err());
        assert!(Tensor::from_bytes(b"EFT1\x09\x00").is_err(), "bad dtype tag");
        let t = Tensor::f32(vec![4], vec![0.0; 4]).unwrap();
        let mut bytes = t.to_bytes();
        bytes.truncate(bytes.len() - 1);
        assert!(Tensor::from_bytes(&bytes).is_err(), "truncated data");
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);

        let ti = Tensor::i32(vec![2], vec![7, -9]).unwrap();
        let lit = ti.to_literal().unwrap();
        assert_eq!(Tensor::from_literal(&lit).unwrap(), ti);
    }

    #[test]
    fn typed_accessors() {
        let t = Tensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        assert_eq!(t.dtype().name(), "f32");
        assert_eq!(DType::parse("i32").unwrap(), DType::I32);
        assert!(DType::parse("f64").is_err());
    }

    /// Property: random tensors roundtrip through the wire format.
    #[test]
    fn prop_wire_roundtrip() {
        let mut rng = crate::util::rng::Pcg32::seeded(21);
        for _ in 0..100 {
            let rank = rng.next_below(4) as usize;
            let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.next_below(8) as usize).collect();
            let n: usize = shape.iter().product();
            let t = if rng.next_bool(0.5) {
                Tensor::f32(shape, (0..n).map(|_| rng.next_f32() - 0.5).collect()).unwrap()
            } else {
                Tensor::i32(shape, (0..n).map(|_| rng.next_u32() as i32).collect()).unwrap()
            };
            assert_eq!(Tensor::from_bytes(&t.to_bytes()).unwrap(), t);
        }
    }
}
