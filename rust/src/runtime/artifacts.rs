//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! `artifacts/manifest.json` records, for every AOT-lowered entry, the HLO
//! file plus input/output shapes and dtypes. The runtime validates every
//! execution against this contract so shape bugs surface as errors at the
//! boundary, not as garbage numerics.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{parse, Json};

use super::tensor::{DType, Tensor};

/// Shape + dtype of one tensor in an entry signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    fn from_json(v: &Json) -> anyhow::Result<TensorSpec> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_u64().map(|x| x as usize).ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let dtype = DType::parse(v.req_str("dtype")?)?;
        Ok(TensorSpec { shape, dtype })
    }

    /// Does a tensor match this spec?
    pub fn matches(&self, t: &Tensor) -> bool {
        t.shape == self.shape && t.dtype() == self.dtype
    }

    pub fn describe(&self) -> String {
        format!("{}{:?}", self.dtype.name(), self.shape)
    }
}

/// One AOT entry.
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub fingerprint: String,
    pub entries: BTreeMap<String, Entry>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {path:?}: {e} (run `make artifacts` first)"))?;
        Self::parse_text(&text, dir)
    }

    fn parse_text(text: &str, dir: PathBuf) -> anyhow::Result<Manifest> {
        let v = parse(text)?;
        let fingerprint = v.req_str("fingerprint")?.to_string();
        let mut entries = BTreeMap::new();
        let obj = v
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing entries"))?;
        for (name, e) in obj {
            let file = dir.join(e.req_str("file")?);
            let parse_specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("entry {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                Entry { name: name.clone(), file, inputs: parse_specs("inputs")?, outputs: parse_specs("outputs")? },
            );
        }
        Ok(Manifest { dir, fingerprint, entries })
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact entry `{name}` (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// Validate a set of inputs against an entry's signature.
    pub fn validate_inputs(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<()> {
        let entry = self.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            anyhow::bail!("{name}: expected {} inputs, got {}", entry.inputs.len(), inputs.len());
        }
        for (i, (spec, t)) in entry.inputs.iter().zip(inputs).enumerate() {
            if !spec.matches(t) {
                anyhow::bail!(
                    "{name}: input {i} expected {}, got {}{:?}",
                    spec.describe(),
                    t.dtype().name(),
                    t.shape
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "fingerprint": "abc123",
      "entries": {
        "fedavg_k4": {
          "file": "fedavg_k4.hlo.txt",
          "inputs": [
            {"shape": [4, 61706], "dtype": "f32"},
            {"shape": [4], "dtype": "f32"}
          ],
          "outputs": [{"shape": [61706], "dtype": "f32"}]
        },
        "lenet_predict": {
          "file": "lenet_predict.hlo.txt",
          "inputs": [
            {"shape": [61706], "dtype": "f32"},
            {"shape": [32, 1, 28, 28], "dtype": "f32"}
          ],
          "outputs": [{"shape": [32], "dtype": "i32"}]
        }
      }
    }"#;

    fn sample() -> Manifest {
        Manifest::parse_text(SAMPLE, PathBuf::from("/tmp/artifacts")).unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = sample();
        assert_eq!(m.fingerprint, "abc123");
        assert_eq!(m.names(), vec!["fedavg_k4", "lenet_predict"]);
        let e = m.entry("fedavg_k4").unwrap();
        assert_eq!(e.inputs[0].shape, vec![4, 61706]);
        assert_eq!(e.outputs[0].dtype, DType::F32);
        assert!(e.file.ends_with("fedavg_k4.hlo.txt"));
    }

    #[test]
    fn unknown_entry_lists_alternatives() {
        let err = sample().entry("nope").unwrap_err().to_string();
        assert!(err.contains("fedavg_k4"), "{err}");
    }

    #[test]
    fn validate_inputs_checks_arity_shape_dtype() {
        let m = sample();
        let good = vec![
            Tensor::zeros(vec![4, 61706]),
            Tensor::zeros(vec![4]),
        ];
        m.validate_inputs("fedavg_k4", &good).unwrap();
        // Wrong arity.
        assert!(m.validate_inputs("fedavg_k4", &good[..1].to_vec()).is_err());
        // Wrong shape.
        let bad = vec![Tensor::zeros(vec![4, 10]), Tensor::zeros(vec![4])];
        assert!(m.validate_inputs("fedavg_k4", &bad).is_err());
        // Wrong dtype.
        let bad = vec![Tensor::zeros(vec![4, 61706]), Tensor::i32(vec![4], vec![0; 4]).unwrap()];
        assert!(m.validate_inputs("fedavg_k4", &bad).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Integration-lite: if `make artifacts` has run, the real manifest
        // must parse and contain the expected entries.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            for name in ["lenet_train_step", "fedavg_k4", "motion_scores", "knn_classify"] {
                assert!(m.entries.contains_key(name), "missing {name}");
            }
        }
    }
}
