//! Thread-hosted engine service.
//!
//! The `xla` crate's PJRT client is `Rc`-based and therefore neither `Send`
//! nor `Sync`; function handlers run on gateway worker threads. The service
//! owns the [`Engine`] on a dedicated thread and serves execution requests
//! over a channel — the standard actor pattern. PJRT CPU parallelizes
//! inside a computation, so serializing at the request level costs little
//! at this scale (and matches a single accelerator queue on real hardware).

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Mutex;

use super::tensor::Tensor;

enum Request {
    Execute {
        entry: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<anyhow::Result<Vec<Tensor>>>,
    },
    WarmUp {
        entries: Vec<String>,
        reply: mpsc::Sender<anyhow::Result<()>>,
    },
    Shutdown,
}

/// A `Send + Sync` handle to an engine thread.
pub struct EngineService {
    tx: Mutex<mpsc::Sender<Request>>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl EngineService {
    /// Spawn the engine thread over an artifact directory. Fails fast if the
    /// manifest is unreadable or the PJRT client cannot start.
    pub fn start(artifacts_dir: impl Into<PathBuf>) -> anyhow::Result<EngineService> {
        let dir = artifacts_dir.into();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let thread = std::thread::Builder::new().name("pjrt-engine".into()).spawn(move || {
            let engine = match super::client::Engine::new(&dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Execute { entry, inputs, reply } => {
                        let _ = reply.send(engine.execute(&entry, &inputs));
                    }
                    Request::WarmUp { entries, reply } => {
                        let names: Vec<&str> = entries.iter().map(String::as_str).collect();
                        let _ = reply.send(engine.warm_up(&names));
                    }
                    Request::Shutdown => break,
                }
            }
        })?;
        ready_rx.recv().map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(EngineService { tx: Mutex::new(tx), thread: Mutex::new(Some(thread)) })
    }

    /// Execute an artifact entry (see [`super::client::Engine::execute`]).
    pub fn execute(&self, entry: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Execute {
                entry: entry.to_string(),
                inputs: inputs.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("engine thread dropped request"))?
    }

    /// Pre-compile entries.
    pub fn warm_up(&self, entries: &[&str]) -> anyhow::Result<()> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::WarmUp {
                entries: entries.iter().map(|s| s.to_string()).collect(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("engine thread dropped request"))?
    }
}

impl Drop for EngineService {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Request::Shutdown);
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn concurrent_clients_share_one_engine() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = Arc::new(EngineService::start(dir).unwrap());
        svc.warm_up(&["fedavg_k2"]).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|k| {
                let svc = Arc::clone(&svc);
                std::thread::spawn(move || {
                    let p = 61706;
                    let mut stacked = vec![k as f32; p];
                    stacked.extend(vec![(k + 2) as f32; p]);
                    let out = svc
                        .execute(
                            "fedavg_k2",
                            &[
                                Tensor::f32(vec![2, p], stacked).unwrap(),
                                Tensor::f32(vec![2], vec![1.0, 1.0]).unwrap(),
                            ],
                        )
                        .unwrap();
                    let avg = out[0].as_f32().unwrap();
                    assert!((avg[0] - (k as f32 + 1.0)).abs() < 1e-6);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn bad_entry_propagates_error() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = EngineService::start(dir).unwrap();
        assert!(svc.execute("nonexistent", &[]).is_err());
    }

    #[test]
    fn missing_artifacts_fail_fast() {
        assert!(EngineService::start("/nonexistent/path").is_err());
    }
}
