//! PJRT runtime: executes the AOT artifacts produced by `python/compile/`.
//!
//! * [`tensor`] — host tensors + the wire format function payloads use;
//! * [`artifacts`] — the manifest contract written by `aot.py`;
//! * [`client`] — the PJRT engine (HLO text -> compile -> execute, cached).
//!
//! Python never runs here: the rust binary is self-contained once
//! `make artifacts` has produced `artifacts/*.hlo.txt`.

pub mod artifacts;
pub mod client;
pub mod service;
pub mod tensor;

pub use artifacts::Manifest;
pub use client::Engine;
pub use service::EngineService;
pub use tensor::{DType, Tensor};
