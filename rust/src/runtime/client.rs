//! PJRT execution engine: load HLO-text artifacts, compile once, execute
//! from the coordinator's request path.
//!
//! This is the runtime half of the AOT bridge (see `python/compile/aot.py`):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile` -> `execute`. Executables are compiled lazily on
//! first use and cached for the life of the engine; the request path then
//! pays only literal conversion + execution.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use super::artifacts::Manifest;
use super::tensor::Tensor;

/// The PJRT-backed execution engine for all AOT artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create an engine over the artifact directory (expects manifest.json).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        log::info!(
            "PJRT engine up: platform={} devices={} entries={:?}",
            client.platform_name(),
            client.device_count(),
            manifest.names()
        );
        Ok(Engine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for an entry.
    fn executable(&self, name: &str) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let entry = self.manifest.entry(name)?;
        let start = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .map_err(|e| anyhow::anyhow!("parse {:?}: {e}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        log::info!("compiled `{name}` in {:.2}s", start.elapsed().as_secs_f64());
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Pre-compile a set of entries (warm-up before serving).
    pub fn warm_up(&self, names: &[&str]) -> anyhow::Result<()> {
        for name in names {
            self.executable(name)?;
        }
        Ok(())
    }

    /// Execute an entry. Inputs are validated against the manifest; outputs
    /// come back as host tensors (the AOT lowering wraps results in a tuple,
    /// which is unpacked here).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.manifest.validate_inputs(name, inputs)?;
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<anyhow::Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
        let buffer = &result[0][0];
        let root = buffer
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e}"))?;
        let entry = self.manifest.entry(name)?;
        if parts.len() != entry.outputs.len() {
            anyhow::bail!("{name}: expected {} outputs, got {}", entry.outputs.len(), parts.len());
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (i, (part, spec)) in parts.iter().zip(&entry.outputs).enumerate() {
            let t = Tensor::from_literal(part)?;
            if !spec.matches(&t) {
                anyhow::bail!(
                    "{name}: output {i} expected {}, got {}{:?}",
                    spec.describe(),
                    t.dtype().name(),
                    t.shape
                );
            }
            outs.push(t);
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn engine() -> Option<Engine> {
        artifacts_dir().map(|d| Engine::new(d).unwrap())
    }

    #[test]
    fn fedavg_numerics_match_reference() {
        let Some(eng) = engine() else { return };
        let p = 61706;
        // Workers: constant vectors 1, 2, 3, 4 with weights 1, 1, 1, 1 -> 2.5.
        let mut stacked = Vec::with_capacity(4 * p);
        for k in 0..4 {
            stacked.extend(std::iter::repeat((k + 1) as f32).take(p));
        }
        let inputs = vec![
            Tensor::f32(vec![4, p], stacked).unwrap(),
            Tensor::f32(vec![4], vec![1.0; 4]).unwrap(),
        ];
        let out = eng.execute("fedavg_k4", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let avg = out[0].as_f32().unwrap();
        assert!(avg.iter().all(|&x| (x - 2.5).abs() < 1e-6), "fedavg mean");
    }

    #[test]
    fn motion_scores_flag_keyframe_and_still_scene() {
        let Some(eng) = engine() else { return };
        let (t, h, w) = (24, 96, 160);
        let frames = Tensor::f32(vec![t, h, w], vec![0.5; t * h * w]).unwrap();
        let out = eng.execute("motion_scores", &[frames]).unwrap();
        let scores = out[0].as_f32().unwrap();
        assert_eq!(scores.len(), t);
        assert_eq!(scores[0], 1.0);
        assert!(scores[1..].iter().all(|&s| s.abs() < 1e-6));
    }

    #[test]
    fn lenet_predict_shape_contract() {
        let Some(eng) = engine() else { return };
        let p = 61706;
        let params = Tensor::zeros(vec![p]);
        let images = Tensor::zeros(vec![32, 1, 28, 28]);
        let out = eng.execute("lenet_predict", &[params, images]).unwrap();
        assert_eq!(out[0].shape, vec![32]);
        // Zero params -> uniform logits -> argmax 0 everywhere.
        assert!(out[0].as_i32().unwrap().iter().all(|&c| c == 0));
    }

    #[test]
    fn train_step_decreases_loss_on_separable_batch() {
        let Some(eng) = engine() else { return };
        let p = 61706;
        // Deterministic "digits": class-dependent bright square.
        let mut rng = crate::util::rng::Pcg32::seeded(42);
        let mut images = vec![0.0f32; 32 * 28 * 28];
        let mut labels = vec![0i32; 32];
        for i in 0..32 {
            let lbl = (i % 10) as i32;
            labels[i] = lbl;
            let cy = 4 + 2 * (lbl as usize % 5);
            let cx = 4 + 4 * (lbl as usize / 5);
            for dy in 0..6 {
                for dx in 0..6 {
                    images[i * 784 + (cy + dy) * 28 + cx + dx] = 1.0;
                }
            }
        }
        // He-scaled init per layer so gradients flow through the tanh stack
        // (layout mirrors python/compile/model.py LENET_SHAPES).
        let layers: [(usize, f32); 10] = [
            (150, (2.0f32 / 25.0).sqrt()),   // conv1_w
            (6, 0.0),                        // conv1_b
            (2400, (2.0f32 / 150.0).sqrt()), // conv2_w
            (16, 0.0),                       // conv2_b
            (48000, (2.0f32 / 400.0).sqrt()),
            (120, 0.0),
            (10080, (2.0f32 / 120.0).sqrt()),
            (84, 0.0),
            (840, (2.0f32 / 84.0).sqrt()),
            (10, 0.0),
        ];
        let mut params = Vec::with_capacity(p);
        for (n, scale) in layers {
            for _ in 0..n {
                params.push(rng.next_gaussian() as f32 * scale);
            }
        }
        assert_eq!(params.len(), p);
        let mut params_t = Tensor::f32(vec![p], params).unwrap();
        let images_t = Tensor::f32(vec![32, 1, 28, 28], images).unwrap();
        let labels_t = Tensor::i32(vec![32], labels).unwrap();
        let mut losses = Vec::new();
        for _ in 0..10 {
            let out = eng
                .execute(
                    "lenet_train_step",
                    &[params_t.clone(), images_t.clone(), labels_t.clone(), Tensor::scalar(0.3)],
                )
                .unwrap();
            params_t = out[0].clone();
            losses.push(out[1].item().unwrap());
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.9),
            "loss must fall: {losses:?}"
        );
    }

    #[test]
    fn knn_classifies_gallery_rows_exactly() {
        let Some(eng) = engine() else { return };
        let (b, g, d) = (8, 32, 64);
        let mut rng = crate::util::rng::Pcg32::seeded(7);
        let gallery: Vec<f32> = (0..g * d).map(|_| rng.next_f32() - 0.5).collect();
        let labels: Vec<i32> = (0..g as i32).collect();
        // Queries = first 8 gallery rows.
        let queries = gallery[..b * d].to_vec();
        let out = eng
            .execute(
                "knn_classify",
                &[
                    Tensor::f32(vec![b, d], queries).unwrap(),
                    Tensor::f32(vec![g, d], gallery).unwrap(),
                    Tensor::i32(vec![g], labels).unwrap(),
                ],
            )
            .unwrap();
        let pred = out[0].as_i32().unwrap();
        assert_eq!(pred, &(0..b as i32).collect::<Vec<_>>()[..]);
        let dist = out[1].as_f32().unwrap();
        assert!(dist.iter().all(|&x| x < 1e-3));
    }

    #[test]
    fn input_validation_rejects_bad_shapes() {
        let Some(eng) = engine() else { return };
        let bad = vec![Tensor::zeros(vec![3, 61706]), Tensor::zeros(vec![4])];
        let err = eng.execute("fedavg_k4", &bad).unwrap_err().to_string();
        assert!(err.contains("input 0"), "{err}");
    }

    #[test]
    fn executable_cache_returns_same_compilation() {
        let Some(eng) = engine() else { return };
        eng.warm_up(&["fedavg_k2"]).unwrap();
        let a = eng.executable("fedavg_k2").unwrap();
        let b = eng.executable("fedavg_k2").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
    }
}
