//! Minimal logger for the `log` facade.
//!
//! Level is selected by `EDGEFAAS_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`. Output goes to stderr with a monotonic timestamp so
//! interleaved coordinator / gateway / sandbox logs are orderable.

use std::sync::OnceLock;
use std::time::Instant;

struct Logger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for Logger {
    fn enabled(&self, meta: &log::Metadata) -> bool {
        meta.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed();
        eprintln!(
            "[{:>9.3}s {:5} {}] {}",
            t.as_secs_f64(),
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

/// Install the global logger (idempotent).
pub fn init() {
    let level = match std::env::var("EDGEFAAS_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| Logger { start: Instant::now(), level });
    // set_logger fails if called twice; that's fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
