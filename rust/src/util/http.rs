//! HTTP/1.1 server and client over `std::net::TcpStream`.
//!
//! The paper's whole control plane is RESTful: the unified EdgeFaaS gateway,
//! the per-resource OpenFaaS/faasd gateways, the MinIO endpoints, and the
//! Prometheus scrape endpoints all speak HTTP. The offline build has no
//! hyper/tokio, so this module implements the needed subset: request/response
//! framing with `Content-Length` bodies, a threadpool-backed listener, and a
//! blocking client. Chunked transfer, TLS and keep-alive pipelining are out
//! of scope (every exchange is one request/response on a fresh connection,
//! which matches how OpenFaaS CLI-style clients behave).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::threadpool::ThreadPool;

/// Maximum accepted body size (128 MiB — a 92 MB paper video fits).
pub const MAX_BODY: usize = 128 << 20;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Decoded query parameters.
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> anyhow::Result<&str> {
        Ok(std::str::from_utf8(&self.body)?)
    }

    pub fn json(&self) -> anyhow::Result<super::json::Json> {
        super::json::parse(self.body_str()?)
    }

    /// Path segments (split on '/', empty segments removed).
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response { status, headers: BTreeMap::new(), body: Vec::new() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        let mut r = Response::new(status);
        r.headers.insert("Content-Type".into(), "text/plain".into());
        r.body = body.into().into_bytes();
        r
    }

    pub fn json(status: u16, v: &super::json::Json) -> Response {
        let mut r = Response::new(status);
        r.headers.insert("Content-Type".into(), "application/json".into());
        r.body = v.to_string().into_bytes();
        r
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Response {
        let mut r = Response::new(status);
        r.headers.insert("Content-Type".into(), "application/octet-stream".into());
        r.body = body;
        r
    }

    pub fn not_found() -> Response {
        Response::text(404, "not found")
    }

    pub fn bad_request(msg: impl Into<String>) -> Response {
        Response::text(400, msg)
    }

    pub fn error(msg: impl Into<String>) -> Response {
        Response::text(500, msg)
    }

    pub fn ok(&self) -> bool {
        (200..300).contains(&self.status)
    }

    pub fn body_str(&self) -> anyhow::Result<&str> {
        Ok(std::str::from_utf8(&self.body)?)
    }

    pub fn json_body(&self) -> anyhow::Result<super::json::Json> {
        super::json::parse(self.body_str()?)
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            202 => "Accepted",
            204 => "No Content",
            400 => "Bad Request",
            401 => "Unauthorized",
            403 => "Forbidden",
            404 => "Not Found",
            409 => "Conflict",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            502 => "Bad Gateway",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }
}

/// Request handler trait (object-safe so gateways can be trait objects).
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, req: Request) -> Response;
}

impl<F> Handler for F
where
    F: Fn(Request) -> Response + Send + Sync + 'static,
{
    fn handle(&self, req: Request) -> Response {
        self(req)
    }
}

/// A running HTTP server; dropping it stops the accept loop.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) and serve `handler` on a
    /// pool of `workers` threads.
    pub fn bind(port: u16, workers: usize, handler: Arc<dyn Handler>) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name(format!("http-{}", addr.port()))
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = Arc::clone(&handler);
                            if pool.execute(move || serve_conn(stream, h)).is_err() {
                                break; // workers gone: stop accepting
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address, e.g. `127.0.0.1:43211`.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_conn(stream: TcpStream, handler: Arc<dyn Handler>) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let resp = match read_request(&mut reader) {
        Ok(req) => {
            log::debug!("{} {} from {:?}", req.method, req.path, peer);
            handler.handle(req)
        }
        Err(e) => Response::bad_request(format!("malformed request: {e}")),
    };
    let mut stream = stream;
    let _ = write_response(&mut stream, &resp);
}

fn read_request(reader: &mut BufReader<TcpStream>) -> anyhow::Result<Request> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow::anyhow!("empty request line"))?.to_string();
    let target = parts.next().ok_or_else(|| anyhow::anyhow!("missing path"))?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    if !version.starts_with("HTTP/1") {
        anyhow::bail!("unsupported version {version}");
    }
    let headers = read_headers(reader)?;
    let body = read_body(reader, &headers)?;
    let (path, query) = split_target(&target);
    Ok(Request { method, path, query, headers, body })
}

fn read_headers(reader: &mut impl BufRead) -> anyhow::Result<BTreeMap<String, String>> {
    let mut headers = BTreeMap::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            return Ok(headers);
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
}

fn read_body(
    reader: &mut impl BufRead,
    headers: &BTreeMap<String, String>,
) -> anyhow::Result<Vec<u8>> {
    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .map_err(|_| anyhow::anyhow!("bad content-length"))?
        .unwrap_or(0);
    if len > MAX_BODY {
        anyhow::bail!("body too large: {len}");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(body)
}

fn split_target(target: &str) -> (String, BTreeMap<String, String>) {
    match target.split_once('?') {
        Some((path, qs)) => {
            let query = qs
                .split('&')
                .filter(|s| !s.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (url_decode(k), url_decode(v)),
                    None => (url_decode(kv), String::new()),
                })
                .collect();
            (path.to_string(), query)
        }
        None => (target.to_string(), BTreeMap::new()),
    }
}

/// Percent-decode a URL component.
pub fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() + 1 && i + 2 <= bytes.len() - 1 + 1 => {
                let hex = &s[i + 1..(i + 3).min(s.len())];
                if hex.len() == 2 {
                    if let Ok(b) = u8::from_str_radix(hex, 16) {
                        out.push(b);
                        i += 3;
                        continue;
                    }
                }
                out.push(b'%');
                i += 1;
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Percent-encode a URL component.
pub fn url_encode(s: &str) -> String {
    let mut out = String::new();
    for &b in s.as_bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' | b'/' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> anyhow::Result<()> {
    let mut head = format!("HTTP/1.1 {} {}\r\n", resp.status, resp.reason());
    for (k, v) in &resp.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\nConnection: close\r\n\r\n", resp.body.len()));
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

// ---------------------------------------------------------------- client --

/// Issue a blocking HTTP request to `addr` (`host:port`).
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> anyhow::Result<Response> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    stream.set_write_timeout(Some(Duration::from_secs(60)))?;
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\n");
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\nConnection: close\r\n\r\n", body.len()));
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("bad status line {status_line:?}"))?;
    let headers = read_headers(&mut reader)?;
    let body = read_body(&mut reader, &headers)?;
    Ok(Response { status, headers, body })
}

/// GET shorthand.
pub fn get(addr: &str, path: &str) -> anyhow::Result<Response> {
    request(addr, "GET", path, &[], &[])
}

/// POST shorthand with a JSON body.
pub fn post_json(addr: &str, path: &str, v: &super::json::Json) -> anyhow::Result<Response> {
    request(addr, "POST", path, &[("Content-Type", "application/json")], v.to_string().as_bytes())
}

/// POST shorthand with raw bytes.
pub fn post_bytes(addr: &str, path: &str, body: &[u8]) -> anyhow::Result<Response> {
    request(addr, "POST", path, &[("Content-Type", "application/octet-stream")], body)
}

/// DELETE shorthand.
pub fn delete(addr: &str, path: &str) -> anyhow::Result<Response> {
    request(addr, "DELETE", path, &[], &[])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn echo_server() -> Server {
        Server::bind(
            0,
            4,
            Arc::new(|req: Request| {
                let mut o = Json::obj();
                o.set("method", req.method.as_str().into())
                    .set("path", req.path.as_str().into())
                    .set("len", req.body.len().into());
                if let Some(q) = req.query.get("q") {
                    o.set("q", q.as_str().into());
                }
                Response::json(200, &o)
            }),
        )
        .unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let server = echo_server();
        let resp = get(&server.addr(), "/hello/world?q=a+b%21").unwrap();
        assert_eq!(resp.status, 200);
        let v = resp.json_body().unwrap();
        assert_eq!(v.req_str("method").unwrap(), "GET");
        assert_eq!(v.req_str("path").unwrap(), "/hello/world");
        assert_eq!(v.req_str("q").unwrap(), "a b!");
    }

    #[test]
    fn post_body_roundtrip() {
        let server = echo_server();
        let body = vec![7u8; 100_000];
        let resp = post_bytes(&server.addr(), "/upload", &body).unwrap();
        assert_eq!(resp.json_body().unwrap().get("len").unwrap().as_u64(), Some(100_000));
    }

    #[test]
    fn concurrent_requests() {
        let server = echo_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let resp = get(&addr, &format!("/r/{i}")).unwrap();
                    assert_eq!(resp.status, 200);
                    assert_eq!(
                        resp.json_body().unwrap().req_str("path").unwrap(),
                        format!("/r/{i}")
                    );
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn not_found_and_errors() {
        let server = Server::bind(0, 2, Arc::new(|_req: Request| Response::not_found())).unwrap();
        let resp = get(&server.addr(), "/whatever").unwrap();
        assert_eq!(resp.status, 404);
        assert!(!resp.ok());
    }

    #[test]
    fn url_codec_roundtrip() {
        for s in ["plain", "a b c", "x%y&z=1", "ünïcode/path", "100%"] {
            assert_eq!(url_decode(&url_encode(s)), s, "roundtrip {s}");
        }
    }

    #[test]
    fn server_stops_on_drop() {
        let server = echo_server();
        let addr = server.addr();
        drop(server);
        std::thread::sleep(Duration::from_millis(30));
        assert!(TcpStream::connect(&addr).is_err() || get(&addr, "/").is_err());
    }
}
