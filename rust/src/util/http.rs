//! HTTP/1.1 server and client over `std::net::TcpStream`.
//!
//! The paper's whole control plane is RESTful: the unified EdgeFaaS gateway,
//! the per-resource OpenFaaS/faasd gateways, the MinIO endpoints, and the
//! Prometheus scrape endpoints all speak HTTP. The offline build has no
//! hyper/tokio, so this module implements the needed subset — now with a
//! connection-oriented fast path:
//!
//! * **Keep-alive server.** Connections serve many requests. On Linux the
//!   listener runs a readiness-driven epoll reactor (raw `extern "C"`
//!   declarations, no crates) owning non-blocking connection state machines:
//!   read-accumulate → parse → hand off to the worker pool → queue write →
//!   flush on writable. Everywhere else (and under
//!   [`ServerOptions::force_fallback`]) a portable thread-per-connection
//!   loop provides the same semantics. Both paths honor
//!   `Connection: keep-alive`/`close`, enforce idle + partial-request
//!   (slowloris) timeouts, and cap requests per connection with a clean
//!   `Connection: close` downgrade.
//! * **Pooled client.** The free functions ([`request`], [`get`],
//!   [`post_json`], [`post_bytes`], [`delete`]) draw keep-alive connections
//!   from a per-address connection pool with health check-on-checkout,
//!   bounded size, and idle eviction. [`request_fresh`] preserves the old
//!   one-shot `Connection: close` behaviour for baselines and benches.
//! * **Zero-copy bodies.** [`Request`] and [`Response`] carry
//!   [`Bytes`](super::bytes::Bytes); parsed request bodies are windows into
//!   the connection's read buffer, and responses go out with one vectored
//!   write (head + body) instead of per-header `format!` appends.
//! * **Deadline budgets + typed errors.** Every client call runs under a
//!   [`RequestOptions`] budget: a connect timeout and a total per-request
//!   deadline enforced with slice-granular reads, so a stalled peer fails
//!   at the budget instead of a socket default. Failures are typed
//!   [`HttpError`]s (downcastable from the returned `anyhow::Error`), so
//!   retry gating and liveness reporting branch on variants, not message
//!   text.
//! * **Fault plane.** Both client paths (pooled and fresh) consult the
//!   process-wide [`faults`](super::faults) injector at connect and
//!   exchange time, so chaos tests and the fault bench can partition,
//!   delay, truncate or reset any peer without touching call sites.
//!
//! Chunked transfer and TLS remain out of scope.

use std::collections::{BTreeMap, HashMap};
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::bytes::Bytes;
use super::faults;
#[cfg(target_os = "linux")]
use super::threadpool::ThreadPool;

/// Maximum accepted body size (128 MiB — a 92 MB paper video fits).
pub const MAX_BODY: usize = 128 << 20;

/// Maximum accepted header block (request line + headers + CRLFCRLF).
const MAX_HEAD: usize = 64 << 10;

/// Granularity of timeout checks on blocking fallback sockets.
const SLICE: Duration = Duration::from_millis(100);

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Decoded query parameters.
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    /// Body as a window into the connection's read buffer (no copy).
    pub body: Bytes,
}

impl Request {
    pub fn body_str(&self) -> anyhow::Result<&str> {
        Ok(std::str::from_utf8(&self.body)?)
    }

    pub fn json(&self) -> anyhow::Result<super::json::Json> {
        super::json::parse(self.body_str()?)
    }

    /// Path segments (split on '/', empty segments removed).
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Bytes,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response { status, headers: BTreeMap::new(), body: Bytes::new() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        let mut r = Response::new(status);
        r.headers.insert("Content-Type".into(), "text/plain".into());
        r.body = Bytes::from(body.into());
        r
    }

    pub fn json(status: u16, v: &super::json::Json) -> Response {
        let mut r = Response::new(status);
        r.headers.insert("Content-Type".into(), "application/json".into());
        r.body = Bytes::from(v.to_string());
        r
    }

    /// Octet-stream response; accepts `Vec<u8>` or an existing [`Bytes`]
    /// (the latter is a refcount bump, not a copy).
    pub fn bytes(status: u16, body: impl Into<Bytes>) -> Response {
        let mut r = Response::new(status);
        r.headers.insert("Content-Type".into(), "application/octet-stream".into());
        r.body = body.into();
        r
    }

    pub fn not_found() -> Response {
        Response::text(404, "not found")
    }

    pub fn bad_request(msg: impl Into<String>) -> Response {
        Response::text(400, msg)
    }

    pub fn error(msg: impl Into<String>) -> Response {
        Response::text(500, msg)
    }

    pub fn ok(&self) -> bool {
        (200..300).contains(&self.status)
    }

    /// Consume the response, failing non-2xx statuses as a typed
    /// [`HttpError::Status`] (downcastable from the `anyhow::Error`).
    pub fn require_ok(self) -> anyhow::Result<Response> {
        if self.ok() {
            Ok(self)
        } else {
            Err(HttpError::Status(self.status).into())
        }
    }

    pub fn body_str(&self) -> anyhow::Result<&str> {
        Ok(std::str::from_utf8(&self.body)?)
    }

    pub fn json_body(&self) -> anyhow::Result<super::json::Json> {
        super::json::parse(self.body_str()?)
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            202 => "Accepted",
            204 => "No Content",
            400 => "Bad Request",
            401 => "Unauthorized",
            403 => "Forbidden",
            404 => "Not Found",
            409 => "Conflict",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            502 => "Bad Gateway",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }
}

/// Request handler trait (object-safe so gateways can be trait objects).
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, req: Request) -> Response;
}

impl<F> Handler for F
where
    F: Fn(Request) -> Response + Send + Sync + 'static,
{
    fn handle(&self, req: Request) -> Response {
        self(req)
    }
}

/// Tunables for a listener; [`Server::bind`] uses [`ServerOptions::default`].
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Serve with the portable thread-per-connection loop even on Linux
    /// (tests use this to exercise both paths on one platform).
    pub force_fallback: bool,
    /// Close a keep-alive connection idle for this long between requests.
    pub idle_timeout: Duration,
    /// Close a connection whose request has arrived only partially for this
    /// long (slowloris guard).
    pub request_timeout: Duration,
    /// After this many requests, answer with `Connection: close`.
    pub max_requests_per_conn: usize,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            force_fallback: false,
            idle_timeout: Duration::from_secs(5),
            request_timeout: Duration::from_secs(10),
            max_requests_per_conn: 1000,
        }
    }
}

/// A running HTTP server; dropping it stops the accept loop.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<AtomicU64>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) and serve `handler` on a
    /// pool of `workers` threads with default options.
    pub fn bind(port: u16, workers: usize, handler: Arc<dyn Handler>) -> anyhow::Result<Server> {
        Server::bind_with(port, workers, handler, ServerOptions::default())
    }

    /// [`Server::bind`] with explicit [`ServerOptions`]. On Linux this runs
    /// the epoll reactor unless `opts.force_fallback` is set; elsewhere the
    /// thread-per-connection fallback always serves.
    pub fn bind_with(
        port: u16,
        workers: usize,
        handler: Arc<dyn Handler>,
        opts: ServerOptions,
    ) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(AtomicU64::new(0));
        #[cfg(target_os = "linux")]
        if !opts.force_fallback {
            let t = epoll::spawn_reactor(
                listener,
                workers,
                handler,
                opts,
                Arc::clone(&stop),
                Arc::clone(&conns),
            )?;
            return Ok(Server { addr, stop, conns, accept_thread: Some(t) });
        }
        let t = spawn_fallback(listener, workers, handler, opts, &stop, &conns)?;
        Ok(Server { addr, stop, conns, accept_thread: Some(t) })
    }

    /// The bound address, e.g. `127.0.0.1:43211`.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Total TCP connections accepted so far (keep-alive reuse means this
    /// can be far below the request count; tests assert on it).
    pub fn connections_accepted(&self) -> u64 {
        self.conns.load(Ordering::Relaxed)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

// ------------------------------------------------- portable fallback path --

fn spawn_fallback(
    listener: TcpListener,
    workers: usize,
    handler: Arc<dyn Handler>,
    opts: ServerOptions,
    stop: &Arc<AtomicBool>,
    conns: &Arc<AtomicU64>,
) -> anyhow::Result<std::thread::JoinHandle<()>> {
    // Keep-alive pins a connection to its thread, so the fallback dedicates
    // a thread per connection instead of a fixed pool slot (a pool would let
    // one idle keep-alive client starve fresh connections). `workers` only
    // sizes the epoll reactor's handler pool.
    let _ = workers;
    listener.set_nonblocking(true)?;
    let stop = Arc::clone(stop);
    let conns = Arc::clone(conns);
    let t = std::thread::Builder::new()
        .name(format!("http-{}", listener.local_addr()?.port()))
        .spawn(move || loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    conns.fetch_add(1, Ordering::Relaxed);
                    let h = Arc::clone(&handler);
                    let o = opts.clone();
                    let s = Arc::clone(&stop);
                    std::thread::spawn(move || serve_conn(stream, h, o, s));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => break,
            }
        })?;
    Ok(t)
}

/// Serve one connection until close/timeout/stop (fallback path). Blocking
/// reads run in `SLICE`-sized timeouts so deadlines and the stop flag are
/// checked between slices.
fn serve_conn(
    stream: TcpStream,
    handler: Arc<dyn Handler>,
    opts: ServerOptions,
    stop: Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(SLICE));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let peer = stream.peer_addr().ok();
    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut served = 0usize;
    'conn: loop {
        // Accumulate until one complete request sits at the front of `buf`.
        let idle_since = Instant::now();
        let mut first_byte_at = if buf.is_empty() { None } else { Some(Instant::now()) };
        let parsed = loop {
            match try_parse(&mut buf) {
                Ok(Some(p)) => break p,
                Ok(None) => {}
                Err(e) => {
                    // Parse error: answer 400 and close.
                    let resp = Response::bad_request(format!("malformed request: {e}"));
                    let _ = write_response(&mut stream, &resp, false);
                    break 'conn;
                }
            }
            if stop.load(Ordering::Relaxed) {
                break 'conn;
            }
            let waited = idle_since.elapsed();
            match first_byte_at {
                // Slowloris guard: a request that arrives only partially.
                Some(t) if t.elapsed() >= opts.request_timeout => break 'conn,
                // Idle between requests (or never sent one): drop silently.
                None if waited >= opts.idle_timeout.max(opts.request_timeout) => break 'conn,
                None if served > 0 && waited >= opts.idle_timeout => break 'conn,
                _ => {}
            }
            let mut chunk = [0u8; 16 * 1024];
            match stream.read(&mut chunk) {
                // EOF with no buffered bytes is a clean close (a client
                // dropping an idle keep-alive conn), not a malformed
                // request; either way nobody is listening for an error.
                Ok(0) => break 'conn,
                Ok(n) => {
                    if first_byte_at.is_none() {
                        first_byte_at = Some(Instant::now());
                    }
                    buf.extend_from_slice(&chunk[..n]);
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(_) => break 'conn,
            }
        };
        served += 1;
        let keep = parsed.keep_alive
            && served < opts.max_requests_per_conn
            && !stop.load(Ordering::Relaxed);
        log::debug!("{} {} from {:?}", parsed.req.method, parsed.req.path, peer);
        let resp = handler.handle(parsed.req);
        if write_response(&mut stream, &resp, keep).is_err() || !keep {
            break;
        }
    }
}

// ------------------------------------------------------- request parsing --

/// One request parsed off the front of a connection buffer.
struct ParsedRequest {
    req: Request,
    /// Whether the client asked to keep the connection open (explicit
    /// `Connection` header, else the HTTP-version default).
    keep_alive: bool,
}

/// Try to parse one complete request from the front of `buf`.
///
/// Returns `Ok(None)` when more bytes are needed. On success the request's
/// bytes are consumed from `buf` (pipelined followers stay in place) and the
/// body is a zero-copy window into the consumed allocation.
fn try_parse(buf: &mut Vec<u8>) -> anyhow::Result<Option<ParsedRequest>> {
    let head_end = match buf.windows(4).position(|w| w == b"\r\n\r\n") {
        Some(p) => p + 4,
        None => {
            if buf.len() > MAX_HEAD {
                anyhow::bail!("header block exceeds {MAX_HEAD} bytes");
            }
            return Ok(None);
        }
    };
    let head = std::str::from_utf8(&buf[..head_end - 4])
        .map_err(|_| anyhow::anyhow!("non-utf8 header block"))?;
    let mut lines = head.lines();
    let line = lines.next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow::anyhow!("empty request line"))?.to_string();
    let target = parts.next().ok_or_else(|| anyhow::anyhow!("missing path"))?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    if !version.starts_with("HTTP/1") {
        anyhow::bail!("unsupported version {version}");
    }
    let http11 = version != "HTTP/1.0";
    let mut headers = BTreeMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .map_err(|_| anyhow::anyhow!("bad content-length"))?
        .unwrap_or(0);
    if len > MAX_BODY {
        anyhow::bail!("body too large: {len}");
    }
    let total = head_end + len;
    if buf.len() < total {
        return Ok(None);
    }
    // Detach this request's bytes; the body becomes a refcounted window.
    let tail = buf.split_off(total);
    let owned = std::mem::replace(buf, tail);
    let body = Bytes::from_vec(owned).slice(head_end, total);
    let (path, query) = split_target(&target);
    let keep_alive = match headers.get("connection").map(|c| c.to_ascii_lowercase()) {
        Some(c) if c.contains("close") => false,
        Some(c) if c.contains("keep-alive") => true,
        _ => http11,
    };
    Ok(Some(ParsedRequest { req: Request { method, path, query, headers, body }, keep_alive }))
}

fn split_target(target: &str) -> (String, BTreeMap<String, String>) {
    match target.split_once('?') {
        Some((path, qs)) => {
            let query = qs
                .split('&')
                .filter(|s| !s.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (url_decode(k), url_decode(v)),
                    None => (url_decode(kv), String::new()),
                })
                .collect();
            (path.to_string(), query)
        }
        None => (target.to_string(), BTreeMap::new()),
    }
}

/// Percent-decode a URL component.
pub fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            // A '%' escape needs two following hex bytes; truncated
            // ("%4", trailing "%") or non-hex escapes pass through
            // literally. Decoding stays byte-based so a multibyte UTF-8
            // char right after '%' can never split a `str` slice.
            b'%' if i + 2 < bytes.len() => {
                let hi = (bytes[i + 1] as char).to_digit(16);
                let lo = (bytes[i + 2] as char).to_digit(16);
                if let (Some(hi), Some(lo)) = (hi, lo) {
                    out.push((hi * 16 + lo) as u8);
                    i += 3;
                } else {
                    out.push(b'%');
                    i += 1;
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Percent-encode a URL component.
pub fn url_encode(s: &str) -> String {
    let mut out = String::new();
    for &b in s.as_bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' | b'/' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

// ------------------------------------------------------ response writing --

/// Serialize the status line + headers into one `String` (single growing
/// buffer, no per-header allocations).
fn encode_head(resp: &Response, keep_alive: bool) -> String {
    let mut head = String::with_capacity(192);
    let _ = write!(head, "HTTP/1.1 {} {}\r\n", resp.status, resp.reason());
    for (k, v) in &resp.headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    let _ = write!(head, "Content-Length: {}\r\n", resp.body.len());
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    head
}

/// Write `head` then `body` with as few syscalls as the kernel allows:
/// vectored writes while the head is unfinished, plain writes after.
fn write_all_vectored(w: &mut impl Write, head: &[u8], body: &[u8]) -> std::io::Result<()> {
    let total = head.len() + body.len();
    let mut done = 0usize;
    while done < total {
        let n = if done < head.len() {
            w.write_vectored(&[IoSlice::new(&head[done..]), IoSlice::new(body)])?
        } else {
            w.write(&body[done - head.len()..])?
        };
        if n == 0 {
            return Err(std::io::ErrorKind::WriteZero.into());
        }
        done += n;
    }
    w.flush()
}

fn write_response(
    stream: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = encode_head(resp, keep_alive);
    write_all_vectored(stream, head.as_bytes(), &resp.body)
}

// -------------------------------------------------- epoll reactor (linux) --

/// Readiness-driven server: one reactor thread multiplexes every connection
/// over `epoll`, handlers run on the worker pool, and finished responses
/// come back through an `eventfd` wakeup. Raw `extern "C"` declarations
/// keep the offline build crate-free (same approach as the vendored shims).
#[cfg(target_os = "linux")]
mod epoll {
    use super::*;
    use std::os::fd::AsRawFd;

    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn close(fd: i32) -> i32;
    }

    const EPOLL_CLOEXEC: i32 = 0x80000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLLRDHUP: u32 = 0x2000;
    const EFD_NONBLOCK: i32 = 0x800;
    const EFD_CLOEXEC: i32 = 0x80000;

    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKE: u64 = 1;
    /// Writes stalled longer than this (peer not draining) kill the conn.
    const WRITE_STALL: Duration = Duration::from_secs(30);

    /// Owned epoll instance; closes its fd on drop.
    struct EpollFd(i32);

    impl EpollFd {
        fn new() -> std::io::Result<EpollFd> {
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(EpollFd(fd))
        }

        fn ctl(&self, op: i32, fd: i32, events: u32, token: u64) -> std::io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            let arg = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
            if unsafe { epoll_ctl(self.0, op, fd, arg) } < 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(())
        }

        fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> usize {
            let n = unsafe {
                epoll_wait(self.0, events.as_mut_ptr(), events.len() as i32, timeout_ms)
            };
            if n < 0 {
                0 // EINTR and friends: treat as an empty tick
            } else {
                n as usize
            }
        }
    }

    impl Drop for EpollFd {
        fn drop(&mut self) {
            unsafe { close(self.0) };
        }
    }

    /// Worker→reactor doorbell over an `eventfd`. Workers hold `Arc` clones,
    /// so the fd outlives the reactor and can never be written after close.
    struct Notifier(i32);

    impl Notifier {
        fn new() -> std::io::Result<Notifier> {
            let fd = unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
            if fd < 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Notifier(fd))
        }

        fn notify(&self) {
            let one: u64 = 1;
            unsafe { write(self.0, &one as *const u64 as *const u8, 8) };
        }

        fn drain(&self) {
            let mut buf = [0u8; 8];
            while unsafe { read(self.0, buf.as_mut_ptr(), 8) } > 0 {}
        }
    }

    impl Drop for Notifier {
        fn drop(&mut self) {
            unsafe { close(self.0) };
        }
    }

    enum ConnState {
        /// Accumulating request bytes.
        Reading,
        /// A request is on the worker pool; its response is not back yet.
        Busy,
        /// Flushing `head` then `body`; `done` counts bytes already written
        /// across both.
        Writing { head: Vec<u8>, body: Bytes, done: usize, keep_alive: bool },
    }

    struct Conn {
        stream: TcpStream,
        buf: Vec<u8>,
        state: ConnState,
        served: usize,
        /// Last byte read or write progress (for idle/slowloris sweeps).
        last_activity: Instant,
        /// Peer half-closed (EOF/RDHUP): finish the in-flight response,
        /// then close instead of keeping alive.
        peer_closed: bool,
    }

    pub(super) fn spawn_reactor(
        listener: TcpListener,
        workers: usize,
        handler: Arc<dyn Handler>,
        opts: ServerOptions,
        stop: Arc<AtomicBool>,
        conns: Arc<AtomicU64>,
    ) -> anyhow::Result<std::thread::JoinHandle<()>> {
        listener.set_nonblocking(true)?;
        let ep = EpollFd::new()?;
        let notifier = Arc::new(Notifier::new()?);
        ep.ctl(EPOLL_CTL_ADD, listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
        ep.ctl(EPOLL_CTL_ADD, notifier.0, EPOLLIN, TOKEN_WAKE)?;
        let port = listener.local_addr()?.port();
        let t = std::thread::Builder::new()
            .name(format!("http-epoll-{port}"))
            .spawn(move || run(listener, ep, notifier, workers, handler, opts, stop, conns))?;
        Ok(t)
    }

    #[allow(clippy::too_many_arguments)]
    fn run(
        listener: TcpListener,
        ep: EpollFd,
        notifier: Arc<Notifier>,
        workers: usize,
        handler: Arc<dyn Handler>,
        opts: ServerOptions,
        stop: Arc<AtomicBool>,
        conns: Arc<AtomicU64>,
    ) {
        let pool = ThreadPool::new(workers);
        // (token, response, keep_alive) triples finished by the pool.
        let done: Arc<Mutex<Vec<(u64, Response, bool)>>> = Arc::default();
        let mut table: HashMap<u64, Conn> = HashMap::new();
        let mut next_token: u64 = 2; // 0 = listener, 1 = eventfd; never reused
        let mut events = vec![EpollEvent { events: 0, data: 0 }; 64];
        while !stop.load(Ordering::Relaxed) {
            let n = ep.wait(&mut events, 100);
            for ev in events.iter().take(n) {
                let (bits, token) = (ev.events, ev.data);
                match token {
                    TOKEN_LISTENER => {
                        accept_all(&listener, &ep, &mut table, &mut next_token, &conns);
                    }
                    TOKEN_WAKE => notifier.drain(),
                    token => {
                        if let Some(conn) = table.get_mut(&token) {
                            let close = on_conn_event(
                                conn, token, bits, &ep, &pool, &done, &notifier, &handler, &opts,
                            );
                            if close {
                                remove(&ep, &mut table, token);
                            }
                        }
                    }
                }
            }
            // Responses finished by workers (the wake may have raced the
            // poll timeout, so always drain the queue).
            let finished: Vec<(u64, Response, bool)> =
                done.lock().unwrap().drain(..).collect();
            for (token, resp, keep) in finished {
                let Some(conn) = table.get_mut(&token) else { continue };
                let keep = keep && !conn.peer_closed && !stop.load(Ordering::Relaxed);
                let head = encode_head(&resp, keep);
                conn.state = ConnState::Writing {
                    head: head.into_bytes(),
                    body: resp.body,
                    done: 0,
                    keep_alive: keep,
                };
                conn.last_activity = Instant::now();
                if flush_then_continue(conn, token, &ep, &pool, &done, &notifier, &handler, &opts) {
                    remove(&ep, &mut table, token);
                }
            }
            sweep(&ep, &mut table, &opts);
        }
        // Reactor exit: drop the table (closes every conn), then the pool
        // joins its workers; the eventfd closes with the last Arc.
    }

    fn accept_all(
        listener: &TcpListener,
        ep: &EpollFd,
        table: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
        conns: &Arc<AtomicU64>,
    ) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    conns.fetch_add(1, Ordering::Relaxed);
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    let token = *next_token;
                    *next_token += 1;
                    if ep
                        .ctl(EPOLL_CTL_ADD, stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, token)
                        .is_ok()
                    {
                        table.insert(
                            token,
                            Conn {
                                stream,
                                buf: Vec::new(),
                                state: ConnState::Reading,
                                served: 0,
                                last_activity: Instant::now(),
                                peer_closed: false,
                            },
                        );
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }

    fn remove(ep: &EpollFd, table: &mut HashMap<u64, Conn>, token: u64) {
        if let Some(conn) = table.remove(&token) {
            let _ = ep.ctl(EPOLL_CTL_DEL, conn.stream.as_raw_fd(), 0, 0);
            // conn.stream drops here, closing the fd after deregistration.
        }
    }

    /// React to readiness on one connection. Returns `true` when the
    /// connection should be closed.
    #[allow(clippy::too_many_arguments)]
    fn on_conn_event(
        conn: &mut Conn,
        token: u64,
        bits: u32,
        ep: &EpollFd,
        pool: &ThreadPool,
        done: &Arc<Mutex<Vec<(u64, Response, bool)>>>,
        notifier: &Arc<Notifier>,
        handler: &Arc<dyn Handler>,
        opts: &ServerOptions,
    ) -> bool {
        if bits & (EPOLLERR | EPOLLHUP) != 0 {
            return true;
        }
        if bits & EPOLLRDHUP != 0 {
            conn.peer_closed = true;
        }
        if bits & EPOLLIN != 0 {
            // Drain the socket (level-triggered: unread bytes would re-fire
            // the event). Pipelined bytes accumulate; parsing happens only
            // in the Reading state, one request in flight per connection.
            let mut chunk = [0u8; 16 * 1024];
            loop {
                match conn.stream.read(&mut chunk) {
                    Ok(0) => {
                        conn.peer_closed = true;
                        break;
                    }
                    Ok(n) => {
                        conn.buf.extend_from_slice(&chunk[..n]);
                        conn.last_activity = Instant::now();
                        if conn.buf.len() > MAX_HEAD + MAX_BODY {
                            return true; // runaway peer
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(_) => return true,
                }
            }
            if matches!(conn.state, ConnState::Reading)
                && dispatch_if_ready(conn, token, ep, pool, done, notifier, handler, opts)
            {
                return true;
            }
        }
        if bits & EPOLLOUT != 0 && matches!(conn.state, ConnState::Writing { .. }) {
            return flush_then_continue(conn, token, ep, pool, done, notifier, handler, opts);
        }
        // EOF while idle with nothing buffered and nothing in flight:
        // clean close, no 400 into a dead socket.
        conn.peer_closed && matches!(conn.state, ConnState::Reading) && conn.buf.is_empty()
    }

    /// Parse `conn.buf`; when a full request is there, hand it to the pool.
    /// Returns `true` when the connection should be closed.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_if_ready(
        conn: &mut Conn,
        token: u64,
        ep: &EpollFd,
        pool: &ThreadPool,
        done: &Arc<Mutex<Vec<(u64, Response, bool)>>>,
        notifier: &Arc<Notifier>,
        handler: &Arc<dyn Handler>,
        opts: &ServerOptions,
    ) -> bool {
        match try_parse(&mut conn.buf) {
            Ok(None) => {
                // Truncated request from a half-closed peer can never
                // complete; drop it silently.
                conn.peer_closed && !conn.buf.is_empty()
            }
            Ok(Some(parsed)) => {
                conn.served += 1;
                conn.state = ConnState::Busy;
                let keep = parsed.keep_alive && conn.served < opts.max_requests_per_conn;
                let h = Arc::clone(handler);
                let d = Arc::clone(done);
                let nf = Arc::clone(notifier);
                let req = parsed.req;
                pool.execute(move || {
                    let resp = h.handle(req);
                    d.lock().unwrap().push((token, resp, keep));
                    nf.notify();
                })
                .is_err() // pool gone: close the connection
            }
            Err(_) => {
                // Parse error: 400, then close. The write goes through the
                // normal Writing state so partial flushes still work.
                conn.served += 1;
                conn.buf.clear();
                let resp = Response::bad_request("malformed request");
                conn.state = ConnState::Writing {
                    head: encode_head(&resp, false).into_bytes(),
                    body: resp.body,
                    done: 0,
                    keep_alive: false,
                };
                flush_then_continue(conn, token, ep, pool, done, notifier, handler, opts)
            }
        }
    }

    /// Flush the Writing state as far as the socket allows; on completion
    /// either close, or go back to Reading and serve any pipelined request.
    /// Returns `true` when the connection should be closed.
    #[allow(clippy::too_many_arguments)]
    fn flush_then_continue(
        conn: &mut Conn,
        token: u64,
        ep: &EpollFd,
        pool: &ThreadPool,
        done: &Arc<Mutex<Vec<(u64, Response, bool)>>>,
        notifier: &Arc<Notifier>,
        handler: &Arc<dyn Handler>,
        opts: &ServerOptions,
    ) -> bool {
        let ConnState::Writing { head, body, done: written, keep_alive } = &mut conn.state else {
            return false;
        };
        let total = head.len() + body.len();
        while *written < total {
            let r = if *written < head.len() {
                conn.stream
                    .write_vectored(&[IoSlice::new(&head[*written..]), IoSlice::new(body)])
            } else {
                conn.stream.write(&body[*written - head.len()..])
            };
            match r {
                Ok(0) => return true,
                Ok(n) => {
                    *written += n;
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // Wait for writability; keep listening for RDHUP.
                    let _ = ep.ctl(
                        EPOLL_CTL_MOD,
                        conn.stream.as_raw_fd(),
                        EPOLLIN | EPOLLOUT | EPOLLRDHUP,
                        token,
                    );
                    return false;
                }
                Err(_) => return true,
            }
        }
        let keep = *keep_alive && !conn.peer_closed;
        if !keep {
            return true;
        }
        conn.state = ConnState::Reading;
        let _ = ep.ctl(EPOLL_CTL_MOD, conn.stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, token);
        // A pipelined follow-up may already be buffered.
        dispatch_if_ready(conn, token, ep, pool, done, notifier, handler, opts)
    }

    /// Close idle keep-alive conns, slowloris partial requests, and stalled
    /// writers. Runs every reactor tick (~100 ms).
    fn sweep(ep: &EpollFd, table: &mut HashMap<u64, Conn>, opts: &ServerOptions) {
        let doomed: Vec<u64> = table
            .iter()
            .filter(|(_, c)| {
                let quiet = c.last_activity.elapsed();
                match &c.state {
                    ConnState::Reading if c.buf.is_empty() => {
                        if c.served > 0 {
                            quiet >= opts.idle_timeout
                        } else {
                            quiet >= opts.idle_timeout.max(opts.request_timeout)
                        }
                    }
                    ConnState::Reading => quiet >= opts.request_timeout,
                    ConnState::Busy => false, // handler owns the clock here
                    ConnState::Writing { .. } => quiet >= WRITE_STALL,
                }
            })
            .map(|(&t, _)| t)
            .collect();
        for token in doomed {
            remove(ep, table, token);
        }
    }
}

// ---------------------------------------------------------------- client --

/// How long an idle pooled connection stays eligible for reuse.
const POOL_IDLE_TTL: Duration = Duration::from_secs(30);

/// Per-address idle-connection cap (see [`set_pool_per_addr`]).
static POOL_PER_ADDR: AtomicUsize = AtomicUsize::new(32);

static POOL: OnceLock<ConnectionPool> = OnceLock::new();

fn pool() -> &'static ConnectionPool {
    POOL.get_or_init(ConnectionPool::default)
}

/// Cap the number of idle keep-alive connections kept per address (process
/// wide). High-fan-in benches raise this to the client count so reuse is
/// not defeated by checkin evictions.
pub fn set_pool_per_addr(n: usize) {
    POOL_PER_ADDR.store(n.max(1), Ordering::Relaxed);
}

struct IdleConn {
    stream: TcpStream,
    since: Instant,
}

/// Process-wide pool of idle keep-alive client connections, keyed by
/// `host:port`. Checkout health-checks each candidate (a server may have
/// closed it while idle); checkin evicts expired entries and bounds the
/// per-address stack.
#[derive(Default)]
struct ConnectionPool {
    idle: Mutex<HashMap<String, Vec<IdleConn>>>,
}

impl ConnectionPool {
    fn checkout(&self, addr: &str) -> Option<TcpStream> {
        let mut map = self.idle.lock().unwrap();
        let list = map.get_mut(addr)?;
        while let Some(c) = list.pop() {
            if c.since.elapsed() <= POOL_IDLE_TTL && stream_is_healthy(&c.stream) {
                return Some(c.stream);
            }
        }
        None
    }

    fn checkin(&self, addr: &str, stream: TcpStream) {
        let mut map = self.idle.lock().unwrap();
        let list = map.entry(addr.to_string()).or_default();
        list.retain(|c| c.since.elapsed() <= POOL_IDLE_TTL);
        if list.len() < POOL_PER_ADDR.load(Ordering::Relaxed) {
            list.push(IdleConn { stream, since: Instant::now() });
        }
    }
}

/// A pooled stream is healthy when a non-blocking peek would block: `Ok(0)`
/// means the server closed it, `Ok(_)` means stray bytes we never asked for.
fn stream_is_healthy(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut probe = [0u8; 1];
    let healthy = matches!(
        stream.peek(&mut probe),
        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock
    );
    stream.set_nonblocking(false).is_ok() && healthy
}

/// Typed client-side failure taxonomy. Every error returned by the client
/// free functions carries one of these as its source (downcast with
/// [`HttpError::of`]), so retry gating and liveness reporting branch on
/// variants instead of message text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HttpError {
    /// The peer's OS refused the connection (nothing is listening — the
    /// classic crashed-process signal).
    ConnectRefused(String),
    /// No connection within the caller's connect budget (a black-holed
    /// SYN: partition or silently dropped traffic).
    ConnectTimeout(String),
    /// The per-request deadline budget expired mid-exchange (a stalled or
    /// partitioned peer on an established connection).
    Deadline(String),
    /// The connection died mid-exchange (reset/aborted/broken pipe). The
    /// request *may* have executed — never blindly retried for
    /// non-idempotent verbs.
    Reset(String),
    /// The response was cut short (EOF inside headers or body).
    Truncated(String),
    /// The peer answered, but not with parseable HTTP.
    Malformed(String),
    /// The peer answered with a non-2xx status (only produced by callers
    /// that require success, e.g. [`Response::require_ok`]).
    Status(u16),
}

impl HttpError {
    /// Connection-level evidence the *peer or path* is unhealthy — the
    /// gate for both idempotent-verb retries and data-path liveness
    /// misses. `Malformed`/`Status` are application-level: the peer is
    /// alive and talking, just not saying what we wanted.
    pub fn is_connectivity(&self) -> bool {
        !matches!(self, HttpError::Malformed(_) | HttpError::Status(_))
    }

    /// Downcast an `anyhow::Error` from any client function back to the
    /// typed taxonomy.
    pub fn of(err: &anyhow::Error) -> Option<&HttpError> {
        err.downcast_ref::<HttpError>()
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::ConnectRefused(m) => write!(f, "connection refused: {m}"),
            HttpError::ConnectTimeout(m) => write!(f, "connect timed out: {m}"),
            HttpError::Deadline(m) => write!(f, "deadline budget exhausted: {m}"),
            HttpError::Reset(m) => write!(f, "connection reset: {m}"),
            HttpError::Truncated(m) => write!(f, "response truncated: {m}"),
            HttpError::Malformed(m) => write!(f, "malformed response: {m}"),
            HttpError::Status(s) => write!(f, "http status {s}"),
        }
    }
}

impl std::error::Error for HttpError {}

/// Classify an I/O failure into the typed taxonomy. `phase` names the
/// exchange stage for the error message.
fn classify_io(e: std::io::Error, addr: &str, phase: &str) -> HttpError {
    use std::io::ErrorKind;
    let msg = format!("{phase} ({addr}): {e}");
    match e.kind() {
        ErrorKind::ConnectionRefused => HttpError::ConnectRefused(msg),
        ErrorKind::TimedOut | ErrorKind::WouldBlock => HttpError::Deadline(msg),
        ErrorKind::UnexpectedEof => HttpError::Truncated(msg),
        _ => HttpError::Reset(msg),
    }
}

/// Classify an `anyhow::Error` whose source may be an `io::Error`
/// (transport) or a parse failure (malformed peer).
fn classify_anyhow(e: anyhow::Error, addr: &str, phase: &str) -> HttpError {
    match e.downcast::<std::io::Error>() {
        Ok(io) => classify_io(io, addr, phase),
        Err(e) => HttpError::Malformed(format!("{phase} ({addr}): {e}")),
    }
}

/// Per-request budget for the client free functions.
///
/// `deadline` is the **total** wall budget for one request/response
/// exchange (write + read), enforced with [`SLICE`]-granular socket reads
/// so a peer that stalls mid-body fails at the budget — never at a
/// hard-coded socket default. The previous fixed 60 s read/write socket
/// timeouts are exactly `RequestOptions::default()`, so callers that never
/// opt in keep the old effective cap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestOptions {
    /// Budget for establishing a new connection (ignored when a pooled
    /// connection is reused).
    pub connect_timeout: Duration,
    /// Total budget for the exchange on the established connection.
    pub deadline: Duration,
}

impl Default for RequestOptions {
    fn default() -> RequestOptions {
        RequestOptions {
            connect_timeout: Duration::from_secs(10),
            deadline: Duration::from_secs(60),
        }
    }
}

impl RequestOptions {
    /// Default connect budget with the given total deadline.
    pub fn with_deadline(deadline: Duration) -> RequestOptions {
        RequestOptions { deadline, ..RequestOptions::default() }
    }

    /// Both budgets explicit.
    pub fn budget(connect_timeout: Duration, deadline: Duration) -> RequestOptions {
        RequestOptions { connect_timeout, deadline }
    }
}

/// A [`Read`] view over a `TcpStream` that enforces an absolute deadline
/// with slice-granular socket timeouts: each syscall waits at most
/// [`SLICE`] (or the remaining budget, whichever is smaller), so a peer
/// stalling mid-body surfaces as `TimedOut` within one slice of the
/// budget instead of a 60 s socket default.
struct BudgetReader<'a> {
    stream: &'a TcpStream,
    deadline: Instant,
}

impl Read for BudgetReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            let remaining = self.deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "deadline budget exhausted",
                ));
            }
            self.stream.set_read_timeout(Some(remaining.min(SLICE)))?;
            match self.stream.read(buf) {
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    continue
                }
                r => return r,
            }
        }
    }
}

fn connect_fresh(addr: &str, opts: &RequestOptions) -> Result<TcpStream, HttpError> {
    if faults::active() {
        match faults::injector().connect_fault(addr) {
            Some(faults::ConnectFault::Refused) => {
                return Err(HttpError::ConnectRefused(format!("{addr}: injected fault")));
            }
            Some(faults::ConnectFault::BlackHole) => {
                // A partitioned SYN gets no answer at all: burn the whole
                // connect budget, then time out.
                std::thread::sleep(opts.connect_timeout);
                return Err(HttpError::ConnectTimeout(format!(
                    "{addr}: injected black hole, no answer in {:?}",
                    opts.connect_timeout
                )));
            }
            None => {}
        }
    }
    use std::net::ToSocketAddrs;
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| HttpError::Malformed(format!("resolving {addr}: {e}")))?
        .next()
        .ok_or_else(|| HttpError::Malformed(format!("{addr} resolves to no address")))?;
    let stream = TcpStream::connect_timeout(&sock, opts.connect_timeout).map_err(|e| {
        match e.kind() {
            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock => {
                HttpError::ConnectTimeout(format!("{addr}: {e}"))
            }
            _ => classify_io(e, addr, "connecting"),
        }
    })?;
    let _ = stream.set_nodelay(true);
    Ok(stream)
}

/// Issue a blocking HTTP request to `addr` (`host:port`), reusing a pooled
/// keep-alive connection when one is available. Runs under
/// [`RequestOptions::default`]; see [`request_with`] for explicit budgets.
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> anyhow::Result<Response> {
    request_with(addr, method, path, headers, body, RequestOptions::default())
}

/// [`request`] with an explicit per-request budget.
///
/// A pooled connection can go stale between health check and use (the
/// server closes it as we write); when that happens before any response
/// byte arrives, the request is retried once on a fresh connection. Any
/// failure after response bytes started (or any injected mid-exchange
/// fault) is returned as-is — the request may have executed, and only a
/// caller that knows the verb's idempotency may retry it.
pub fn request_with(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    opts: RequestOptions,
) -> anyhow::Result<Response> {
    let deadline = Instant::now() + opts.deadline;
    if let Some(stream) = pool().checkout(addr) {
        match exchange(stream, addr, method, path, headers, body, true, deadline) {
            Ok(resp) => return Ok(resp),
            // Nothing of the response arrived: the server never processed
            // (or never saw) the request, so a retry is safe.
            Err(ExchangeError::BeforeResponse(_)) => {}
            Err(ExchangeError::MidResponse(e)) => return Err(e),
        }
    }
    let stream = connect_fresh(addr, &opts).map_err(anyhow::Error::new)?;
    exchange(stream, addr, method, path, headers, body, true, deadline)
        .map_err(ExchangeError::into_inner)
}

/// One-shot `Connection: close` request on a fresh connection (the
/// pre-pool behaviour; benches use it as the baseline).
pub fn request_fresh(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> anyhow::Result<Response> {
    request_fresh_with(addr, method, path, headers, body, RequestOptions::default())
}

/// [`request_fresh`] with an explicit per-request budget — the same
/// [`RequestOptions`] contract as the pooled path, so bench baselines
/// stay comparable under identical budgets.
pub fn request_fresh_with(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    opts: RequestOptions,
) -> anyhow::Result<Response> {
    let deadline = Instant::now() + opts.deadline;
    let stream = connect_fresh(addr, &opts).map_err(anyhow::Error::new)?;
    exchange(stream, addr, method, path, headers, body, false, deadline)
        .map_err(ExchangeError::into_inner)
}

/// Failure side of [`exchange`], split on whether any response bytes had
/// arrived (the retry-safety line for pooled connections).
enum ExchangeError {
    BeforeResponse(anyhow::Error),
    MidResponse(anyhow::Error),
}

impl ExchangeError {
    fn into_inner(self) -> anyhow::Error {
        match self {
            ExchangeError::BeforeResponse(e) | ExchangeError::MidResponse(e) => e,
        }
    }
}

/// Send one request and read one response on `stream`, failing typed (as
/// [`HttpError`]) when the absolute `deadline` expires at any point of the
/// exchange. With `keep_alive`, a fully-read response on a connection the
/// server left open goes back to the pool.
///
/// When the fault injector is armed, this is also where mid-exchange
/// faults land: injected latency sleeps against the remaining budget,
/// black holes burn it entirely (→ `Deadline`), probabilistic error rates
/// surface as `Reset`, and truncation cuts the response after its status
/// line (→ `Truncated`). All injected failures are `MidResponse`, so the
/// pooled path's stale-connection retry never silently heals them — only
/// a caller-level retry budget can.
#[allow(clippy::too_many_arguments)]
fn exchange(
    stream: TcpStream,
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
    deadline: Instant,
) -> Result<Response, ExchangeError> {
    let fault = if faults::active() {
        Some(faults::injector().request_fault(addr, method, path, body))
    } else {
        None
    };
    if let Some(f) = &fault {
        if let Some(extra) = f.extra_latency {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if extra >= remaining {
                std::thread::sleep(remaining);
                return Err(ExchangeError::MidResponse(
                    HttpError::Deadline(format!("{addr}: injected latency exceeded budget")).into(),
                ));
            }
            std::thread::sleep(extra);
        }
        if f.black_hole {
            // An established connection into a partition: bytes vanish,
            // nothing ever answers. Burn the remaining budget, then fail.
            std::thread::sleep(deadline.saturating_duration_since(Instant::now()));
            return Err(ExchangeError::MidResponse(
                HttpError::Deadline(format!("{addr}: injected black hole ate the request")).into(),
            ));
        }
        if f.reset {
            return Err(ExchangeError::MidResponse(
                HttpError::Reset(format!("{addr}: injected connection reset")).into(),
            ));
        }
    }
    let mut head = String::with_capacity(192);
    let _ = write!(head, "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n");
    for (k, v) in headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    let _ = write!(head, "Content-Length: {}\r\n", body.len());
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(ExchangeError::BeforeResponse(
                HttpError::Deadline(format!("{addr}: budget exhausted before write")).into(),
            ));
        }
        let _ = stream.set_write_timeout(Some(remaining));
        let mut w = &stream;
        write_all_vectored(&mut w, head.as_bytes(), body).map_err(|e| {
            ExchangeError::BeforeResponse(classify_io(e, addr, "writing request").into())
        })?;
    }

    // Read exactly one response. `BufReader` over the budgeted stream view
    // leaves the stream free to return to the pool; over-buffering cannot
    // eat a later response because the server sends one response per
    // request. `BudgetReader` turns a stalled peer into a typed `Deadline`
    // failure within one read slice of the budget.
    let mut reader = BufReader::new(BudgetReader { stream: &stream, deadline });
    let mut status_line = String::new();
    match reader.read_line(&mut status_line) {
        Ok(0) => {
            return Err(ExchangeError::BeforeResponse(
                HttpError::Reset(format!("{addr}: connection closed before response")).into(),
            ))
        }
        Ok(_) => {}
        Err(e) if status_line.is_empty() => {
            return Err(ExchangeError::BeforeResponse(
                classify_io(e, addr, "awaiting response").into(),
            ))
        }
        Err(e) => {
            return Err(ExchangeError::MidResponse(
                classify_io(e, addr, "reading status line").into(),
            ))
        }
    }
    if fault.as_ref().is_some_and(|f| f.truncate) {
        // The response died mid-body; the connection is poisoned — never
        // pooled.
        return Err(ExchangeError::MidResponse(
            HttpError::Truncated(format!("{addr}: injected mid-body truncation")).into(),
        ));
    }
    let parse = || -> anyhow::Result<Response> {
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad status line {status_line:?}"))?;
        let headers = read_headers(&mut reader)?;
        let body = Bytes::from_vec(read_body(&mut reader, &headers)?);
        Ok(Response { status, headers, body })
    };
    let resp = parse()
        .map_err(|e| ExchangeError::MidResponse(classify_anyhow(e, addr, "reading response").into()))?;
    let server_keeps = resp
        .headers
        .get("connection")
        .map(|c| !c.eq_ignore_ascii_case("close"))
        .unwrap_or(true);
    drop(reader);
    if keep_alive && server_keeps {
        pool().checkin(addr, stream);
    }
    Ok(resp)
}

fn read_headers(reader: &mut impl BufRead) -> anyhow::Result<BTreeMap<String, String>> {
    let mut headers = BTreeMap::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            // io-typed so the client classifies it as `Truncated`.
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-headers",
            )
            .into());
        }
        let line = line.trim_end();
        if line.is_empty() {
            return Ok(headers);
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
}

fn read_body(
    reader: &mut impl BufRead,
    headers: &BTreeMap<String, String>,
) -> anyhow::Result<Vec<u8>> {
    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .map_err(|_| anyhow::anyhow!("bad content-length"))?
        .unwrap_or(0);
    if len > MAX_BODY {
        anyhow::bail!("body too large: {len}");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(body)
}

/// GET shorthand.
pub fn get(addr: &str, path: &str) -> anyhow::Result<Response> {
    request(addr, "GET", path, &[], &[])
}

/// POST shorthand with a JSON body.
pub fn post_json(addr: &str, path: &str, v: &super::json::Json) -> anyhow::Result<Response> {
    request(addr, "POST", path, &[("Content-Type", "application/json")], v.to_string().as_bytes())
}

/// POST shorthand with raw bytes.
pub fn post_bytes(addr: &str, path: &str, body: &[u8]) -> anyhow::Result<Response> {
    request(addr, "POST", path, &[("Content-Type", "application/octet-stream")], body)
}

/// DELETE shorthand.
pub fn delete(addr: &str, path: &str) -> anyhow::Result<Response> {
    request(addr, "DELETE", path, &[], &[])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::net::Shutdown;

    fn echo_handler() -> Arc<dyn Handler> {
        Arc::new(|req: Request| {
            let mut o = Json::obj();
            o.set("method", req.method.as_str().into())
                .set("path", req.path.as_str().into())
                .set("len", req.body.len().into());
            if let Some(q) = req.query.get("q") {
                o.set("q", q.as_str().into());
            }
            Response::json(200, &o)
        })
    }

    fn echo_server() -> Server {
        Server::bind(0, 4, echo_handler()).unwrap()
    }

    fn echo_server_with(opts: ServerOptions) -> Server {
        Server::bind_with(0, 4, echo_handler(), opts).unwrap()
    }

    /// Both serving paths, exercised on one platform (on non-Linux the
    /// "default" variant is the fallback anyway).
    fn both_paths(f: impl Fn(ServerOptions)) {
        f(ServerOptions::default());
        f(ServerOptions { force_fallback: true, ..ServerOptions::default() });
    }

    #[test]
    fn get_roundtrip() {
        both_paths(|opts| {
            let server = echo_server_with(opts);
            let resp = get(&server.addr(), "/hello/world?q=a+b%21").unwrap();
            assert_eq!(resp.status, 200);
            let v = resp.json_body().unwrap();
            assert_eq!(v.req_str("method").unwrap(), "GET");
            assert_eq!(v.req_str("path").unwrap(), "/hello/world");
            assert_eq!(v.req_str("q").unwrap(), "a b!");
        });
    }

    #[test]
    fn post_body_roundtrip() {
        both_paths(|opts| {
            let server = echo_server_with(opts);
            let body = vec![7u8; 100_000];
            let resp = post_bytes(&server.addr(), "/upload", &body).unwrap();
            assert_eq!(resp.json_body().unwrap().get("len").unwrap().as_u64(), Some(100_000));
        });
    }

    #[test]
    fn concurrent_requests() {
        let server = echo_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let resp = get(&addr, &format!("/r/{i}")).unwrap();
                    assert_eq!(resp.status, 200);
                    assert_eq!(
                        resp.json_body().unwrap().req_str("path").unwrap(),
                        format!("/r/{i}")
                    );
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn concurrent_64_clients_smoke() {
        // 64 simultaneous pooled clients against the default (epoll on
        // Linux) server — the high-fan-in shape the reactor exists for.
        set_pool_per_addr(64);
        let server = echo_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..64)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    for j in 0..4 {
                        let resp = get(&addr, &format!("/c/{i}/{j}")).unwrap();
                        assert_eq!(resp.status, 200);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn not_found_and_errors() {
        let server = Server::bind(0, 2, Arc::new(|_req: Request| Response::not_found())).unwrap();
        let resp = get(&server.addr(), "/whatever").unwrap();
        assert_eq!(resp.status, 404);
        assert!(!resp.ok());
    }

    #[test]
    fn url_codec_roundtrip() {
        for s in ["plain", "a b c", "x%y&z=1", "ünïcode/path", "100%"] {
            assert_eq!(url_decode(&url_encode(s)), s, "roundtrip {s}");
        }
    }

    #[test]
    fn url_decode_truncated_and_invalid_escapes() {
        // Truncated escapes pass through literally instead of tripping the
        // old contorted bounds logic.
        assert_eq!(url_decode("%4"), "%4");
        assert_eq!(url_decode("%"), "%");
        assert_eq!(url_decode("abc%"), "abc%");
        assert_eq!(url_decode("%zz"), "%zz");
        assert_eq!(url_decode("%41"), "A");
        assert_eq!(url_decode("%4g"), "%4g");
        // Multibyte UTF-8 right after '%' must not panic (the old code
        // sliced the &str at a byte offset inside the char).
        assert_eq!(url_decode("%aé"), "%aé");
        assert_eq!(url_decode("%%41"), "%A");
    }

    #[test]
    fn keep_alive_reuses_one_connection() {
        both_paths(|opts| {
            let server = echo_server_with(opts);
            let addr = server.addr();
            for i in 0..3 {
                let resp = get(&addr, &format!("/ka/{i}")).unwrap();
                assert_eq!(resp.status, 200);
            }
            assert_eq!(server.connections_accepted(), 1, "pooled requests share one conn");
        });
    }

    #[test]
    fn fresh_requests_open_one_connection_each() {
        let server = echo_server();
        let addr = server.addr();
        for _ in 0..3 {
            assert_eq!(request_fresh(&addr, "GET", "/", &[], &[]).unwrap().status, 200);
        }
        assert_eq!(server.connections_accepted(), 3);
    }

    #[test]
    fn max_requests_per_conn_downgrades_to_close() {
        both_paths(|opts| {
            let server = echo_server_with(ServerOptions { max_requests_per_conn: 2, ..opts });
            let addr = server.addr();
            for i in 0..4 {
                assert_eq!(get(&addr, &format!("/m/{i}")).unwrap().status, 200);
            }
            // Requests 1-2 ride conn 1 (closed after 2), 3-4 ride conn 2.
            assert_eq!(server.connections_accepted(), 2);
        });
    }

    #[test]
    fn stale_pooled_connection_is_replaced() {
        both_paths(|opts| {
            let server = echo_server_with(ServerOptions {
                idle_timeout: Duration::from_millis(100),
                ..opts
            });
            let addr = server.addr();
            assert_eq!(get(&addr, "/a").unwrap().status, 200);
            // Server closes the idle conn; the pool's copy is now stale.
            std::thread::sleep(Duration::from_millis(500));
            assert_eq!(get(&addr, "/b").unwrap().status, 200, "transparent retry");
            assert_eq!(server.connections_accepted(), 2);
        });
    }

    #[test]
    fn slowloris_partial_request_is_dropped() {
        both_paths(|opts| {
            let server = echo_server_with(ServerOptions {
                request_timeout: Duration::from_millis(200),
                idle_timeout: Duration::from_millis(200),
                ..opts
            });
            let addr = server.addr();
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(b"GET /slow HTT").unwrap();
            std::thread::sleep(Duration::from_millis(800));
            let mut buf = Vec::new();
            let n = s.read_to_end(&mut buf).unwrap_or(0);
            assert_eq!(n, 0, "server must drop, not answer: {buf:?}");
            // And the listener still serves others.
            assert_eq!(get(&addr, "/after").unwrap().status, 200);
        });
    }

    #[test]
    fn clean_eof_gets_no_error_response() {
        both_paths(|opts| {
            let server = echo_server_with(opts);
            let mut s = TcpStream::connect(server.addr()).unwrap();
            s.shutdown(Shutdown::Write).unwrap();
            let mut buf = Vec::new();
            let n = s.read_to_end(&mut buf).unwrap_or(0);
            assert_eq!(n, 0, "no 400 into a closing socket: {buf:?}");
        });
    }

    #[test]
    fn malformed_request_gets_400_then_close() {
        both_paths(|opts| {
            let server = echo_server_with(opts);
            let mut s = TcpStream::connect(server.addr()).unwrap();
            s.write_all(b"GARBAGE\r\n\r\n").unwrap();
            let mut buf = String::new();
            let mut reader = BufReader::new(&s);
            reader.read_line(&mut buf).unwrap();
            assert!(buf.starts_with("HTTP/1.1 400"), "got {buf:?}");
        });
    }

    #[test]
    fn pipelined_requests_each_get_a_response() {
        both_paths(|opts| {
            let server = echo_server_with(opts);
            let mut s = TcpStream::connect(server.addr()).unwrap();
            let two = "GET /p/1 HTTP/1.1\r\nContent-Length: 0\r\n\r\n\
                       GET /p/2 HTTP/1.1\r\nContent-Length: 0\r\nConnection: close\r\n\r\n";
            s.write_all(two.as_bytes()).unwrap();
            let mut reader = BufReader::new(&s);
            for expect in ["/p/1", "/p/2"] {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(line.starts_with("HTTP/1.1 200"), "got {line:?}");
                let headers = read_headers(&mut reader).unwrap();
                let body = read_body(&mut reader, &headers).unwrap();
                let v = crate::util::json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
                assert_eq!(v.req_str("path").unwrap(), expect);
            }
        });
    }

    #[test]
    fn server_stops_on_drop_with_live_keepalive_conns() {
        both_paths(|opts| {
            let server = echo_server_with(opts);
            let addr = server.addr();
            // Leave a live keep-alive connection idle in the pool.
            assert_eq!(get(&addr, "/warm").unwrap().status, 200);
            let t0 = Instant::now();
            drop(server);
            assert!(t0.elapsed() < Duration::from_secs(2), "drop must not hang");
            std::thread::sleep(Duration::from_millis(50));
            assert!(
                TcpStream::connect(&addr).is_err() || get(&addr, "/").is_err(),
                "listener must be gone"
            );
        });
    }

    #[test]
    fn body_is_zero_copy_window() {
        // A parsed body shares the connection buffer's allocation.
        let mut buf = b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nhelloTAIL".to_vec();
        let parsed = try_parse(&mut buf).unwrap().unwrap();
        assert_eq!(parsed.req.body, &b"hello"[..]);
        assert!(parsed.keep_alive);
        assert_eq!(buf, b"TAIL", "pipelined tail stays buffered");
    }

    #[test]
    fn parse_connection_header_semantics() {
        let mut buf = b"GET / HTTP/1.0\r\n\r\n".to_vec();
        assert!(!try_parse(&mut buf).unwrap().unwrap().keep_alive, "1.0 defaults to close");
        let mut buf = b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n".to_vec();
        assert!(try_parse(&mut buf).unwrap().unwrap().keep_alive);
        let mut buf = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n".to_vec();
        assert!(!try_parse(&mut buf).unwrap().unwrap().keep_alive);
        let mut buf = b"GET / HTT".to_vec();
        assert!(try_parse(&mut buf).unwrap().is_none(), "incomplete head");
    }

    // ------------------------------------------- typed error taxonomy --

    /// Expect `err` to carry the given `HttpError` variant (by
    /// discriminant, ignoring the message payload).
    fn expect_variant(err: &anyhow::Error, want: &str) {
        let got = HttpError::of(err).unwrap_or_else(|| panic!("untyped error: {err:#}"));
        let name = match got {
            HttpError::ConnectRefused(_) => "ConnectRefused",
            HttpError::ConnectTimeout(_) => "ConnectTimeout",
            HttpError::Deadline(_) => "Deadline",
            HttpError::Reset(_) => "Reset",
            HttpError::Truncated(_) => "Truncated",
            HttpError::Malformed(_) => "Malformed",
            HttpError::Status(_) => "Status",
        };
        assert_eq!(name, want, "wrong variant: {got}");
    }

    /// One-shot raw peer: accept one connection, read the request head,
    /// then run `after` with the stream. Returns its address.
    fn one_shot_peer(
        after: impl FnOnce(TcpStream) + Send + 'static,
    ) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut buf = [0u8; 4096];
            let _ = stream.read(&mut buf);
            after(stream);
        });
        (addr, handle)
    }

    #[test]
    fn refused_connect_is_typed() {
        // Bind then immediately free a port: nothing listens on it.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = request_fresh(&addr, "GET", "/", &[], &[]).unwrap_err();
        expect_variant(&err, "ConnectRefused");
        assert!(HttpError::of(&err).unwrap().is_connectivity());
    }

    #[test]
    fn black_holed_connect_times_out_at_budget() {
        let _g = faults::test_guard();
        faults::injector().install(5);
        faults::injector().add_rule(faults::FaultRule::new(
            "10.88.0.1:7000",
            faults::FaultKind::BlackHole,
        ));
        let opts = RequestOptions::budget(Duration::from_millis(60), Duration::from_secs(5));
        let t0 = Instant::now();
        let err = request_fresh_with("10.88.0.1:7000", "GET", "/", &[], &[], opts).unwrap_err();
        faults::injector().clear();
        expect_variant(&err, "ConnectTimeout");
        let dt = t0.elapsed();
        assert!(
            dt >= Duration::from_millis(60) && dt < Duration::from_secs(2),
            "connect budget, not a socket default: {dt:?}"
        );
    }

    #[test]
    fn stalled_peer_fails_at_deadline_not_socket_default() {
        // The peer accepts and reads the request but never answers: the
        // pre-budget client would sit on its 60 s socket timeout here.
        let (addr, peer) = one_shot_peer(|stream| {
            std::thread::sleep(Duration::from_secs(3));
            drop(stream);
        });
        let opts = RequestOptions::with_deadline(Duration::from_millis(300));
        let t0 = Instant::now();
        let err = request_fresh_with(&addr, "GET", "/stall", &[], &[], opts).unwrap_err();
        expect_variant(&err, "Deadline");
        let dt = t0.elapsed();
        assert!(dt < Duration::from_secs(2), "failed at the budget: {dt:?}");
        peer.join().unwrap();
    }

    #[test]
    fn injected_error_rate_surfaces_as_reset() {
        let _g = faults::test_guard();
        let server = echo_server();
        let addr = server.addr();
        faults::injector().install(9);
        faults::injector()
            .add_rule(faults::FaultRule::new(&addr, faults::FaultKind::ErrorRate { rate: 1.0 }));
        let err = get(&addr, "/flaky").unwrap_err();
        faults::injector().clear();
        expect_variant(&err, "Reset");
        // Healed, the same request succeeds.
        assert_eq!(get(&addr, "/flaky").unwrap().status, 200);
    }

    #[test]
    fn injected_truncation_is_typed_and_not_pooled() {
        let _g = faults::test_guard();
        let server = echo_server();
        let addr = server.addr();
        faults::injector().install(13);
        faults::injector()
            .add_rule(faults::FaultRule::new(&addr, faults::FaultKind::TruncateBody));
        let err = get(&addr, "/cut").unwrap_err();
        faults::injector().clear();
        expect_variant(&err, "Truncated");
    }

    #[test]
    fn real_mid_body_eof_is_truncated() {
        let (addr, peer) = one_shot_peer(|mut stream| {
            // Promise 100 body bytes, deliver 2, hang up.
            let _ = stream.write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 100\r\n\r\nhi");
            let _ = stream.shutdown(Shutdown::Both);
        });
        let err = request_fresh(&addr, "GET", "/", &[], &[]).unwrap_err();
        expect_variant(&err, "Truncated");
        peer.join().unwrap();
    }

    #[test]
    fn garbage_peer_is_malformed_not_connectivity() {
        let (addr, peer) = one_shot_peer(|mut stream| {
            let _ = stream.write_all(b"not http at all\r\n\r\n");
            let _ = stream.shutdown(Shutdown::Both);
        });
        let err = request_fresh(&addr, "GET", "/", &[], &[]).unwrap_err();
        expect_variant(&err, "Malformed");
        assert!(!HttpError::of(&err).unwrap().is_connectivity(), "peer is alive, just wrong");
        peer.join().unwrap();
    }

    #[test]
    fn require_ok_types_non_2xx_statuses() {
        let server = Server::bind(0, 2, Arc::new(|_req: Request| Response::not_found())).unwrap();
        let err = get(&server.addr(), "/x").unwrap().require_ok().unwrap_err();
        assert_eq!(HttpError::of(&err), Some(&HttpError::Status(404)));
        assert!(!HttpError::of(&err).unwrap().is_connectivity());
    }

    #[test]
    fn injected_latency_delays_but_succeeds_within_budget() {
        let _g = faults::test_guard();
        let server = echo_server();
        let addr = server.addr();
        faults::injector().install(17);
        faults::injector().add_rule(faults::FaultRule::new(
            &addr,
            faults::FaultKind::Latency {
                base: Duration::from_millis(80),
                jitter: Duration::ZERO,
            },
        ));
        let t0 = Instant::now();
        let resp = get(&addr, "/slow");
        faults::injector().clear();
        assert_eq!(resp.unwrap().status, 200);
        assert!(t0.elapsed() >= Duration::from_millis(80), "latency rule applied");
    }
}
