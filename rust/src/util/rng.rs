//! Seedable pseudo-random number generators.
//!
//! The offline build has no `rand` crate, so we carry two small,
//! well-known generators: PCG-XSH-RR 64/32 (O'Neill 2014) for synthetic
//! workload *content* (video frames, digit corpus, jittered simulation
//! parameters, property tests) and [`SplitMix64`] (Steele/Lea/Flood 2014)
//! for the scale harness's population generator, where the one-u64-state
//! split discipline — derive an independent child stream per (seed,
//! stream-id) pair — keeps every device's arrival process reproducible
//! from a single population seed. Neither is cryptographic; both are
//! deterministic for a given seed.

/// PCG-XSH-RR 64/32 generator. Deterministic for a given `(seed, stream)`.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next 32 uniformly random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift with rejection.
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let l = m as u32;
            if l >= bound || l >= (bound.wrapping_neg() % bound) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.next_below((hi - lo) as u32) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli draw with probability `p`.
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.next_below(xs.len() as u32) as usize]
    }
}

/// SplitMix64 (Steele, Lea & Flood, "Fast splittable pseudorandom number
/// generators", OOPSLA 2014): a 64-bit state advanced by the golden-ratio
/// increment and finalized with two xor-shift-multiply rounds. Its virtue
/// here is *splitting*: [`SplitMix64::split`] derives a statistically
/// independent child generator, so one population seed fans out into one
/// stream per device with no coordination and no correlation between
/// streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

const SM64_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn seeded(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(SM64_GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Derive an independent child stream keyed by `stream`: the parent's
    /// next output is mixed with the golden-ratio-scaled key and run
    /// through one warm-up round, so `split(a)` and `split(b)` diverge
    /// even for adjacent keys. The parent advances once per split, so
    /// derivation order matters — callers split in a fixed, documented
    /// order (the population generator: the archetype-assignment stream
    /// first, then one stream per device in index order).
    pub fn split(&mut self, stream: u64) -> SplitMix64 {
        let mut child = SplitMix64 { state: self.next_u64() ^ stream.wrapping_mul(SM64_GAMMA) };
        child.next_u64(); // warm up: decorrelate adjacent keys' first draws
        child
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially distributed draw with the given rate (events/sec):
    /// the inter-arrival time of a Poisson process.
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "next_exp needs a positive rate");
        // 1 - U avoids ln(0).
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Uniform in `[0, bound)` by 128-bit multiply-shift (bias < 2^-64,
    /// irrelevant at workload-generation scale).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn next_below_is_in_bounds_and_covers() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f32_f64_unit_interval() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..1000 {
            let f = rng.next_f32();
            assert!((0.0..1.0).contains(&f));
            let d = rng.next_f64();
            assert!((0.0..1.0).contains(&d));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seeded(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(11);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_bounds() {
        let mut rng = Pcg32::seeded(13);
        for _ in 0..100 {
            let v = rng.range(5, 15);
            assert!((5..15).contains(&v));
        }
    }

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 1234567 under the Vigna reference
        // recurrence — pins the exact sequence so population schedules
        // can never silently drift across refactors.
        let mut rng = SplitMix64::seeded(1234567);
        let first: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
        assert_eq!(
            first,
            vec![
                6457827717110365317,
                3203168211198807973,
                9817491932198370423,
                4593380528125082431,
            ]
        );
        let mut other = SplitMix64::seeded(1234568);
        assert!(first != (0..4).map(|_| other.next_u64()).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent_and_reproducible() {
        let mut parent_a = SplitMix64::seeded(99);
        let a0 = parent_a.split(0).next_u64();
        let a1 = parent_a.split(1).next_u64();
        // Same parent seed: sibling streams diverge from each other but
        // reproduce exactly on a second derivation in the same order.
        assert_ne!(a0, a1, "adjacent streams must not collide");
        let mut parent_b = SplitMix64::seeded(99);
        assert_eq!(a0, parent_b.split(0).next_u64());
        assert_eq!(a1, parent_b.split(1).next_u64());
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut rng = SplitMix64::seeded(5);
        let rate = 4.0;
        let n = 50_000;
        let mean = (0..n).map(|_| rng.next_exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
        let below = (0..1000).map(|_| rng.next_below(10)).max().unwrap();
        assert!(below < 10);
    }
}
