//! Shared byte buffers plus byte-size parsing and formatting.
//!
//! [`Bytes`] is the data plane's payload type: an `Arc<[u8]>`-backed,
//! immutable buffer whose clone and slice are refcount bumps, not copies.
//! The object stores hold `Bytes` so `get_object` never copies, function
//! invocation envelopes are `Bytes` shared between the engine's batch and
//! per-task paths, and handler outputs travel back as `Bytes`. Copies happen
//! only at true process boundaries (the loopback HTTP gateways).
//!
//! The size helpers interpret the paper's registration YAML capacities
//! (`64GB`, `1024MB`, `512GB` — Tables 1-3); this module is the single
//! place those units are interpreted.

use std::sync::Arc;

/// A cheaply clonable, sliceable, immutable byte buffer.
///
/// Backed by an `Arc<[u8]>` plus a window: `clone()` and [`Bytes::slice`]
/// bump a refcount instead of copying the payload. Dereferences to `&[u8]`,
/// so existing slice-based code (`parse`, `from_utf8_lossy`, tensor
/// decoders) works on a `&Bytes` unchanged.
#[derive(Clone)]
pub struct Bytes {
    data: Arc<[u8]>,
    start: usize,
    end: usize,
}

impl Bytes {
    /// An empty buffer (no allocation is shared, but none is needed).
    pub fn new() -> Bytes {
        Bytes::from(Vec::new())
    }

    /// Take ownership of a `Vec` (one move into the shared allocation).
    pub fn from_vec(v: Vec<u8>) -> Bytes {
        let data: Arc<[u8]> = Arc::from(v);
        let end = data.len();
        Bytes { data, start: 0, end }
    }

    /// Copy a borrowed slice into a fresh shared buffer (the one place a
    /// copy is explicit: the caller keeps ownership of its bytes).
    pub fn copy_from(s: &[u8]) -> Bytes {
        let data: Arc<[u8]> = Arc::from(s);
        let end = data.len();
        Bytes { data, start: 0, end }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data[self.start..self.end]
    }

    /// A sub-window sharing the same allocation (refcount bump, no copy).
    /// `start..end` is relative to this buffer; panics when out of bounds.
    pub fn slice(&self, start: usize, end: usize) -> Bytes {
        assert!(start <= end && end <= self.len(), "slice {start}..{end} of {}", self.len());
        Bytes { data: Arc::clone(&self.data), start: self.start + start, end: self.start + end }
    }

    /// Copy out to an owned `Vec` (for callers that must own, e.g. HTTP
    /// response bodies).
    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }
}

impl Default for Bytes {
    fn default() -> Bytes {
        Bytes::new()
    }
}

impl std::ops::Deref for Bytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Bytes {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Cap the preview: this type exists to carry large payloads, and a
        // debug-log or panic message must not dump megabytes of bytes.
        const PREVIEW: usize = 32;
        if self.len() <= PREVIEW {
            write!(f, "Bytes({} B: {:?})", self.len(), self.as_slice())
        } else {
            write!(f, "Bytes({} B: {:?}…)", self.len(), &self.as_slice()[..PREVIEW])
        }
    }
}

impl PartialEq for Bytes {
    fn eq(&self, other: &Bytes) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Bytes {}

impl PartialEq<Vec<u8>> for Bytes {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[u8]> for Bytes {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for Bytes {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl<const N: usize> PartialEq<[u8; N]> for Bytes {
    fn eq(&self, other: &[u8; N]) -> bool {
        self.as_slice() == other
    }
}

impl<const N: usize> PartialEq<&[u8; N]> for Bytes {
    fn eq(&self, other: &&[u8; N]) -> bool {
        self.as_slice() == *other
    }
}

impl From<Vec<u8>> for Bytes {
    fn from(v: Vec<u8>) -> Bytes {
        Bytes::from_vec(v)
    }
}

impl From<&[u8]> for Bytes {
    fn from(s: &[u8]) -> Bytes {
        Bytes::copy_from(s)
    }
}

impl From<String> for Bytes {
    fn from(s: String) -> Bytes {
        Bytes::from_vec(s.into_bytes())
    }
}

impl From<&str> for Bytes {
    fn from(s: &str) -> Bytes {
        Bytes::copy_from(s.as_bytes())
    }
}

/// Parse a human size string (`64GB`, `1024MB`, `4 KB`, `92mb`, `1024`) into
/// bytes. Decimal (SI, 1000-based) vs binary is a perennial ambiguity; the
/// paper mixes them loosely, so we follow common systems convention and use
/// 1024-based units, accepting `K/M/G/T` with optional `B`/`iB` suffixes.
pub fn parse_size(s: &str) -> anyhow::Result<u64> {
    let t = s.trim();
    let split = t
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(t.len());
    let (num, unit) = t.split_at(split);
    let num: f64 = num
        .parse()
        .map_err(|_| anyhow::anyhow!("bad size number in `{s}`"))?;
    let unit = unit.trim().trim_end_matches('B').trim_end_matches('b');
    let unit = unit.trim_end_matches('i').trim_end_matches('I');
    let mult: u64 = match unit.to_ascii_uppercase().as_str() {
        "" => 1,
        "K" => 1 << 10,
        "M" => 1 << 20,
        "G" => 1 << 30,
        "T" => 1 << 40,
        other => anyhow::bail!("unknown size unit `{other}` in `{s}`"),
    };
    Ok((num * mult as f64).round() as u64)
}

/// Format bytes with a binary unit, e.g. `92.0 MB`.
pub fn fmt_size(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_clone_and_slice_share_the_allocation() {
        let b = Bytes::from(vec![1u8, 2, 3, 4, 5]);
        let c = b.clone();
        assert_eq!(b, c);
        // Same backing allocation: slices point into the same memory.
        assert_eq!(b.as_slice().as_ptr(), c.as_slice().as_ptr());
        let mid = b.slice(1, 4);
        assert_eq!(mid.as_slice(), &[2, 3, 4]);
        assert_eq!(mid.len(), 3);
        // Sub-slice of a slice stays within the original allocation.
        let inner = mid.slice(1, 2);
        assert_eq!(inner.as_slice(), &[3]);
        assert_eq!(inner.as_slice().as_ptr(), unsafe { b.as_slice().as_ptr().add(2) });
    }

    #[test]
    fn bytes_conversions_and_equality() {
        let from_vec = Bytes::from(vec![104, 105]);
        let from_str = Bytes::from("hi");
        let from_slice = Bytes::from(&b"hi"[..]);
        assert_eq!(from_vec, from_str);
        assert_eq!(from_str, from_slice);
        assert_eq!(from_vec, vec![104, 105]);
        assert_eq!(from_vec, &b"hi"[..]);
        // Array literals too (HTTP tests compare response bodies this way).
        assert_eq!(from_vec, *b"hi");
        assert_eq!(from_vec, b"hi");
        assert!(Bytes::new().is_empty());
        assert_eq!(Bytes::default().len(), 0);
        // Deref lets slice-based helpers take &Bytes directly.
        assert_eq!(std::str::from_utf8(&from_str).unwrap(), "hi");
        assert_eq!(from_vec.to_vec(), vec![104, 105]);
    }

    #[test]
    #[should_panic]
    fn bytes_slice_out_of_bounds_panics() {
        Bytes::from(vec![1u8, 2]).slice(1, 3);
    }

    #[test]
    fn parses_paper_units() {
        assert_eq!(parse_size("64GB").unwrap(), 64 << 30);
        assert_eq!(parse_size("1024MB").unwrap(), 1 << 30);
        assert_eq!(parse_size("512GB").unwrap(), 512 << 30);
        assert_eq!(parse_size("4 KB").unwrap(), 4096);
        assert_eq!(parse_size("100").unwrap(), 100);
        assert_eq!(parse_size("1.5GiB").unwrap(), 3 << 29);
    }

    #[test]
    fn rejects_junk() {
        assert!(parse_size("abc").is_err());
        assert!(parse_size("12XB").is_err());
        assert!(parse_size("").is_err());
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_size(100), "100 B");
        assert_eq!(fmt_size(92 << 20), "92.0 MB");
        assert_eq!(fmt_size(4 << 30), "4.0 GB");
    }

    #[test]
    fn roundtrip_property() {
        let mut rng = crate::util::rng::Pcg32::seeded(5);
        for _ in 0..200 {
            let n = (rng.next_u64() % (1 << 40)) & !0x3ff;
            let s = fmt_size(n);
            let back = parse_size(&s).unwrap();
            // fmt rounds to 1 decimal; allow 5% slack.
            let err = (back as f64 - n as f64).abs() / (n.max(1) as f64);
            assert!(err < 0.05, "{n} -> {s} -> {back}");
        }
    }
}
