//! Byte-size parsing and formatting.
//!
//! The paper's registration YAML expresses capacities as `64GB`, `1024MB`,
//! `512GB` (Tables 1-3); the data-size figures report MB. This module is the
//! single place those units are interpreted.

/// Parse a human size string (`64GB`, `1024MB`, `4 KB`, `92mb`, `1024`) into
/// bytes. Decimal (SI, 1000-based) vs binary is a perennial ambiguity; the
/// paper mixes them loosely, so we follow common systems convention and use
/// 1024-based units, accepting `K/M/G/T` with optional `B`/`iB` suffixes.
pub fn parse_size(s: &str) -> anyhow::Result<u64> {
    let t = s.trim();
    let split = t
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(t.len());
    let (num, unit) = t.split_at(split);
    let num: f64 = num
        .parse()
        .map_err(|_| anyhow::anyhow!("bad size number in `{s}`"))?;
    let unit = unit.trim().trim_end_matches('B').trim_end_matches('b');
    let unit = unit.trim_end_matches('i').trim_end_matches('I');
    let mult: u64 = match unit.to_ascii_uppercase().as_str() {
        "" => 1,
        "K" => 1 << 10,
        "M" => 1 << 20,
        "G" => 1 << 30,
        "T" => 1 << 40,
        other => anyhow::bail!("unknown size unit `{other}` in `{s}`"),
    };
    Ok((num * mult as f64).round() as u64)
}

/// Format bytes with a binary unit, e.g. `92.0 MB`.
pub fn fmt_size(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_units() {
        assert_eq!(parse_size("64GB").unwrap(), 64 << 30);
        assert_eq!(parse_size("1024MB").unwrap(), 1 << 30);
        assert_eq!(parse_size("512GB").unwrap(), 512 << 30);
        assert_eq!(parse_size("4 KB").unwrap(), 4096);
        assert_eq!(parse_size("100").unwrap(), 100);
        assert_eq!(parse_size("1.5GiB").unwrap(), 3 << 29);
    }

    #[test]
    fn rejects_junk() {
        assert!(parse_size("abc").is_err());
        assert!(parse_size("12XB").is_err());
        assert!(parse_size("").is_err());
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_size(100), "100 B");
        assert_eq!(fmt_size(92 << 20), "92.0 MB");
        assert_eq!(fmt_size(4 << 30), "4.0 GB");
    }

    #[test]
    fn roundtrip_property() {
        let mut rng = crate::util::rng::Pcg32::seeded(5);
        for _ in 0..200 {
            let n = (rng.next_u64() % (1 << 40)) & !0x3ff;
            let s = fmt_size(n);
            let back = parse_size(&s).unwrap();
            // fmt rounds to 1 decimal; allow 5% slack.
            let err = (back as f64 - n as f64).abs() / (n.max(1) as f64);
            assert!(err < 0.05, "{n} -> {s} -> {back}");
        }
    }
}
