//! Minimal JSON: a value type, a recursive-descent parser, and a serializer.
//!
//! Used for the durable mapping backup (the paper backs its mappings up in
//! S3/DynamoDB as objects), REST request/response bodies on the gateways,
//! and the artifact manifest emitted by `python/compile/aot.py`.
//!
//! Supports the full JSON grammar (RFC 8259) with the usual rust-side
//! simplifications: numbers are `f64`, object keys keep insertion order.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects keep a sorted key map (deterministic serialization)
/// plus an insertion-order side list is unnecessary for our use cases.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Fetch a required string field, with a path-aware error.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field `{key}`"))
    }

    /// Fetch a required numeric field.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field `{key}`"))
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error with byte offset on malformed input.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            anyhow::bail!("expected `{}` at byte {}", b as char, self.pos.saturating_sub(1))
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => anyhow::bail!("unexpected `{}` at byte {}", c as char, self.pos),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text.parse().map_err(|_| anyhow::anyhow!("bad number `{text}`"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xd800..0xdc00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                            s.push(char::from_u32(c).ok_or_else(|| {
                                anyhow::anyhow!("bad surrogate pair at byte {}", self.pos)
                            })?);
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| {
                                anyhow::anyhow!("bad \\u escape at byte {}", self.pos)
                            })?);
                        }
                    }
                    _ => anyhow::bail!("bad escape at byte {}", self.pos),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: find the sequence length and re-decode.
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..self.pos)
                        .ok_or_else(|| anyhow::anyhow!("truncated UTF-8 at byte {start}"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }

    fn hex4(&mut self) -> anyhow::Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| anyhow::anyhow!("bad hex digit at byte {}", self.pos))?;
        }
        Ok(v)
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => anyhow::bail!("expected `,` or `]` at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => anyhow::bail!("expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xf0 {
        4
    } else if first >= 0xe0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\ slash \u{1f600} ünïcødé";
        let v = Json::Str(s.to_string());
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(parse(r#""A😀""#).unwrap(), Json::Str("A😀".into()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "tru", "01x", "[1] trailing"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integer_serialization_is_exact() {
        assert_eq!(Json::Num(7.0).to_string(), "7");
        assert_eq!(Json::Num(7.5).to_string(), "7.5");
    }

    #[test]
    fn builder_accessors() {
        let mut o = Json::obj();
        o.set("name", "cloud".into()).set("nodes", 10u64.into()).set("up", true.into());
        assert_eq!(o.req_str("name").unwrap(), "cloud");
        assert_eq!(o.get("nodes").unwrap().as_u64(), Some(10));
        assert_eq!(o.get("up").unwrap().as_bool(), Some(true));
        assert!(o.req_str("missing").is_err());
    }

    /// Property: random JSON trees round-trip through serialize -> parse.
    #[test]
    fn prop_roundtrip_random_trees() {
        fn gen(rng: &mut Pcg32, depth: usize) -> Json {
            match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.next_bool(0.5)),
                2 => Json::Num((rng.next_u32() as f64 / 64.0).floor() / 16.0),
                3 => {
                    let n = rng.next_below(8) as usize;
                    Json::Str((0..n).map(|_| (b'a' + rng.next_below(26) as u8) as char).collect())
                }
                4 => {
                    let n = rng.next_below(4) as usize;
                    Json::Arr((0..n).map(|_| gen(rng, depth - 1)).collect())
                }
                _ => {
                    let n = rng.next_below(4) as usize;
                    let mut m = BTreeMap::new();
                    for i in 0..n {
                        m.insert(format!("k{i}"), gen(rng, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let mut rng = Pcg32::seeded(2024);
        for _ in 0..200 {
            let v = gen(&mut rng, 4);
            let text = v.to_string();
            let back = parse(&text).unwrap_or_else(|e| panic!("parse {text}: {e}"));
            assert_eq!(back, v, "roundtrip of {text}");
        }
    }
}
