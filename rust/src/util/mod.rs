//! Foundation substrates built in-repo (the build environment is offline, so
//! no serde/tokio/hyper): a YAML-subset parser for the paper's configuration
//! files, a JSON value type for persistence and REST bodies, an HTTP/1.1
//! server and client over `std::net`, a fixed threadpool, a PCG32 RNG, and a
//! tiny logger for the `log` facade.

pub mod yaml;
pub mod json;
pub mod http;
pub mod faults;
pub mod threadpool;
pub mod rng;
pub mod logging;
pub mod bytes;

/// Render a caught `std::panic::catch_unwind` payload for error messages
/// (used by the panic-containment sites in the engine and the FaaS backend).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}
