//! Deterministic, seeded network fault injection for the HTTP client.
//!
//! Edge links are the defining constraint of edge FaaS: partitions,
//! half-open paths, tail latency, resets. This module is the **fault
//! plane's substrate** — a process-wide injector the client side of
//! [`super::http`] consults at its connect and exchange hooks, so every
//! coordinator verb, `_batch` invoke, object transfer, and `/metrics`
//! scrape can be faulted *without touching a single call site*. The
//! server side is never involved: faults model the wire, not the peer.
//!
//! # Rules
//!
//! A [`FaultRule`] matches a destination address (and optionally the
//! current *source label*, see [`set_source`]) and carries one
//! [`FaultKind`]:
//!
//! * [`FaultKind::ConnectRefused`] — new connections to the peer fail
//!   immediately, the way a crashed process's OS refuses a SYN.
//! * [`FaultKind::BlackHole`] — a partition: connects hang until the
//!   caller's connect budget, established-connection exchanges hang until
//!   the request deadline. Pair two asymmetric rules (or rely on the
//!   source label) to model one-way partitions.
//! * [`FaultKind::Latency`] — adds `base ± jitter` to every matching
//!   exchange (jitter drawn deterministically, see below).
//! * [`FaultKind::TruncateBody`] — the response is cut mid-body: the
//!   client sees the status line arrive and then the connection die
//!   ([`super::http::HttpError::Truncated`]).
//! * [`FaultKind::ErrorRate`] — each matching request independently
//!   fails with probability `rate`, surfaced as a connection reset
//!   *after* the request may have reached the peer
//!   ([`super::http::HttpError::Reset`] — ambiguous, so only budgeted
//!   retry policies recover it, never the transport's silent
//!   before-response retry).
//!
//! # Determinism
//!
//! Probabilistic draws (error rates, latency jitter) must be
//! **interleaving-independent**: the same fault seed must produce the
//! same verdict for the same logical request whether the engine runs 1
//! dispatch shard or 16, and whether a test bed's ephemeral ports came
//! out 40001 or 55317. Draws are therefore keyed by a *stateless request
//! identity*: an FNV-1a hash of `(rule tag, source label, method, path,
//! body)` mixed with the seed — never the raw address, never arrival
//! order. A per-identity occurrence counter (the only mutable state)
//! gives a *re-sent identical request* (a retry) a fresh draw while
//! keeping every draw independent of thread timing. The `tag` defaults
//! to the rule's address but tests give logical names ("res3") so beds
//! rebuilt on new ports replay identically.
//!
//! Disabled by default: [`active`] is a single relaxed atomic load, so
//! the production hot path pays one predictable branch.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, RwLock};
use std::time::Duration;

use super::rng::SplitMix64;

/// What a rule injects (see the module docs for each kind's semantics).
#[derive(Debug, Clone)]
pub enum FaultKind {
    /// Connects to the peer fail immediately (crashed process).
    ConnectRefused,
    /// Partition: connects and exchanges hang until the caller's budget.
    BlackHole,
    /// Add `base ± jitter` to every matching exchange.
    Latency { base: Duration, jitter: Duration },
    /// Cut the response mid-body.
    TruncateBody,
    /// Fail each matching request independently with this probability,
    /// as a mid-exchange connection reset.
    ErrorRate { rate: f64 },
}

/// One fault rule: destination to match, optional source label to match,
/// logical tag for deterministic draws, and the fault to inject.
#[derive(Debug, Clone)]
pub struct FaultRule {
    /// Exact destination address (`host:port`) this rule applies to.
    pub dst: String,
    /// Match only when the process's source label ([`set_source`]) equals
    /// this; `None` matches any source. This is how *asymmetric*
    /// partitions are modeled in a single process: a rule scoped to the
    /// coordinator's label black-holes its traffic while a differently
    /// labelled prober still gets through.
    pub src: Option<String>,
    /// Logical name used in deterministic draws instead of `dst`, so
    /// rebuilding a bed on fresh ephemeral ports replays identically.
    /// Defaults to `dst`.
    pub tag: String,
    pub kind: FaultKind,
}

impl FaultRule {
    /// A rule matching any source, tagged by its address.
    pub fn new(dst: impl Into<String>, kind: FaultKind) -> FaultRule {
        let dst = dst.into();
        FaultRule { tag: dst.clone(), dst, src: None, kind }
    }

    /// Use a logical tag (port-independent) for deterministic draws.
    pub fn tagged(mut self, tag: impl Into<String>) -> FaultRule {
        self.tag = tag.into();
        self
    }

    /// Match only traffic sent under this source label.
    pub fn from_src(mut self, src: impl Into<String>) -> FaultRule {
        self.src = Some(src.into());
        self
    }
}

/// Verdict for a connection attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnectFault {
    /// Fail the connect immediately (ECONNREFUSED).
    Refused,
    /// Sleep the caller's connect budget, then time out.
    BlackHole,
}

/// Verdict for one request on an (assumed) established connection.
#[derive(Debug, Clone, Default)]
pub struct RequestFault {
    /// Added latency (already jittered) to sleep before the exchange.
    pub extra_latency: Option<Duration>,
    /// Partition: stall the remaining deadline budget, then fail.
    pub black_hole: bool,
    /// Probabilistic per-request failure fired for this request: surface
    /// a mid-exchange connection reset.
    pub reset: bool,
    /// Cut the response mid-body.
    pub truncate: bool,
}

impl RequestFault {
    /// True when nothing is injected (the common case).
    pub fn is_clean(&self) -> bool {
        self.extra_latency.is_none() && !self.black_hole && !self.reset && !self.truncate
    }
}

/// The process-wide injector (see module docs). All state is behind the
/// `enabled` flag; when disabled every query is one atomic load.
pub struct FaultInjector {
    enabled: AtomicBool,
    seed: AtomicU64,
    src: RwLock<String>,
    rules: RwLock<Vec<FaultRule>>,
    /// Per-request-identity occurrence counters: how many times this exact
    /// logical request has been seen. Bounded by distinct identities that
    /// matched a probabilistic rule; [`FaultInjector::install`] clears it.
    occurrences: Mutex<HashMap<u64, u64>>,
}

static INJECTOR: OnceLock<FaultInjector> = OnceLock::new();

/// The process-wide injector instance.
pub fn injector() -> &'static FaultInjector {
    INJECTOR.get_or_init(|| FaultInjector {
        enabled: AtomicBool::new(false),
        seed: AtomicU64::new(0),
        src: RwLock::new(String::new()),
        rules: RwLock::new(Vec::new()),
        occurrences: Mutex::new(HashMap::new()),
    })
}

/// Whether any faults are active (one relaxed load — the hot-path guard).
pub fn active() -> bool {
    INJECTOR.get().map(|i| i.enabled.load(Ordering::Relaxed)).unwrap_or(false)
}

/// Serialize tests that touch the process-wide injector. Every test (or
/// bench section) that installs rules must hold this guard for its whole
/// faulted region, so concurrently running tests never see each other's
/// rules.
pub fn test_guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

impl FaultInjector {
    /// Arm the injector under `seed`: clears all rules, occurrence
    /// counters and the source label, then enables fault evaluation.
    pub fn install(&self, seed: u64) {
        self.rules.write().unwrap().clear();
        self.occurrences.lock().unwrap().clear();
        self.src.write().unwrap().clear();
        self.seed.store(seed, Ordering::Relaxed);
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Disarm and drop all rules (the default state).
    pub fn clear(&self) {
        self.enabled.store(false, Ordering::Relaxed);
        self.rules.write().unwrap().clear();
        self.occurrences.lock().unwrap().clear();
        self.src.write().unwrap().clear();
    }

    /// Add a rule (kept in insertion order; for a given destination the
    /// first matching rule of the relevant kind wins).
    pub fn add_rule(&self, rule: FaultRule) {
        self.rules.write().unwrap().push(rule);
    }

    /// Drop every rule matching `dst` (heal one peer's link).
    pub fn heal(&self, dst: &str) {
        self.rules.write().unwrap().retain(|r| r.dst != dst);
    }

    /// Set the process's source label (matched against [`FaultRule::src`]).
    pub fn set_source(&self, label: impl Into<String>) {
        *self.src.write().unwrap() = label.into();
    }

    /// Evaluate the connect-time rules for `dst`. `None` = connect normally.
    pub fn connect_fault(&self, dst: &str) -> Option<ConnectFault> {
        if !self.enabled.load(Ordering::Relaxed) {
            return None;
        }
        let src = self.src.read().unwrap();
        for rule in self.rules.read().unwrap().iter() {
            if rule.dst != dst || !src_matches(&rule.src, &src) {
                continue;
            }
            match rule.kind {
                FaultKind::ConnectRefused => return Some(ConnectFault::Refused),
                FaultKind::BlackHole => return Some(ConnectFault::BlackHole),
                _ => {}
            }
        }
        None
    }

    /// Evaluate the exchange-time rules for one request. Probabilistic
    /// draws are keyed by the stateless request identity (see module
    /// docs), so the verdict is a pure function of (seed, rule tags,
    /// source label, request bytes, occurrence).
    pub fn request_fault(
        &self,
        dst: &str,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> RequestFault {
        let mut out = RequestFault::default();
        if !self.enabled.load(Ordering::Relaxed) {
            return out;
        }
        let src = self.src.read().unwrap();
        let rules = self.rules.read().unwrap();
        for rule in rules.iter() {
            if rule.dst != dst || !src_matches(&rule.src, &src) {
                continue;
            }
            match &rule.kind {
                FaultKind::BlackHole => out.black_hole = true,
                FaultKind::TruncateBody => out.truncate = true,
                FaultKind::Latency { base, jitter } => {
                    let mut rng = self.draw_stream(&rule.tag, &src, method, path, body);
                    let j = if jitter.is_zero() {
                        Duration::ZERO
                    } else {
                        Duration::from_nanos(
                            (rng.next_f64() * 2.0 * jitter.as_nanos() as f64) as u64,
                        )
                    };
                    // base - jitter .. base + jitter, floored at zero.
                    let lat = (*base + j).saturating_sub(*jitter);
                    out.extra_latency =
                        Some(out.extra_latency.map_or(lat, |prev| prev + lat));
                }
                FaultKind::ErrorRate { rate } => {
                    let mut rng = self.draw_stream(&rule.tag, &src, method, path, body);
                    if rng.next_f64() < *rate {
                        out.reset = true;
                    }
                }
                FaultKind::ConnectRefused => {}
            }
        }
        out
    }

    /// Derive the deterministic RNG for one (rule, request) pair: seed ⊕
    /// identity hash, split by this identity's occurrence count so a
    /// retried identical request draws fresh.
    fn draw_stream(
        &self,
        tag: &str,
        src: &str,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> SplitMix64 {
        let identity = fnv1a(&[tag.as_bytes(), src.as_bytes(), method.as_bytes(),
            path.as_bytes(), body]);
        let occurrence = {
            let mut occ = self.occurrences.lock().unwrap();
            let slot = occ.entry(identity).or_insert(0);
            *slot += 1;
            *slot
        };
        let seed = self.seed.load(Ordering::Relaxed);
        SplitMix64::seeded(seed ^ identity).split(occurrence)
    }
}

fn src_matches(rule_src: &Option<String>, current: &str) -> bool {
    match rule_src {
        None => true,
        Some(s) => s == current,
    }
}

/// FNV-1a over the concatenation of the given byte fields, with a length
/// byte between fields so `("ab","c")` and `("a","bc")` hash apart.
fn fnv1a(fields: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for field in fields {
        for &b in *field {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h ^= 0xff;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_injector_is_inert() {
        let _g = test_guard();
        injector().clear();
        assert!(!active());
        assert!(injector().connect_fault("1.2.3.4:80").is_none());
        assert!(injector().request_fault("1.2.3.4:80", "GET", "/", b"").is_clean());
    }

    #[test]
    fn connect_rules_match_destination_exactly() {
        let _g = test_guard();
        let inj = injector();
        inj.install(1);
        inj.add_rule(FaultRule::new("10.0.0.1:80", FaultKind::ConnectRefused));
        inj.add_rule(FaultRule::new("10.0.0.2:80", FaultKind::BlackHole));
        assert_eq!(inj.connect_fault("10.0.0.1:80"), Some(ConnectFault::Refused));
        assert_eq!(inj.connect_fault("10.0.0.2:80"), Some(ConnectFault::BlackHole));
        assert_eq!(inj.connect_fault("10.0.0.3:80"), None);
        inj.heal("10.0.0.1:80");
        assert_eq!(inj.connect_fault("10.0.0.1:80"), None, "healed link connects again");
        inj.clear();
    }

    #[test]
    fn error_rate_draws_are_seed_deterministic_and_tag_keyed() {
        let _g = test_guard();
        let inj = injector();
        let verdicts = |seed: u64| -> Vec<bool> {
            inj.install(seed);
            // Two different ports, same logical tag: draws must agree.
            inj.add_rule(
                FaultRule::new("127.0.0.1:40001", FaultKind::ErrorRate { rate: 0.5 })
                    .tagged("res0"),
            );
            (0..64)
                .map(|i| {
                    let body = format!("req-{i}");
                    inj.request_fault("127.0.0.1:40001", "POST", "/f", body.as_bytes()).reset
                })
                .collect()
        };
        let a = verdicts(42);
        let b = verdicts(42);
        assert_eq!(a, b, "same seed, same identities: same verdicts");
        assert!(a.iter().any(|&v| v) && a.iter().any(|&v| !v), "rate 0.5 mixes outcomes");
        let c = verdicts(43);
        assert_ne!(a, c, "a different seed redraws");

        // The same identities against a *different port* with the same tag
        // replay identically — port-independence is what makes bed rebuilds
        // deterministic.
        inj.install(42);
        inj.add_rule(
            FaultRule::new("127.0.0.1:55317", FaultKind::ErrorRate { rate: 0.5 }).tagged("res0"),
        );
        let d: Vec<bool> = (0..64)
            .map(|i| {
                let body = format!("req-{i}");
                inj.request_fault("127.0.0.1:55317", "POST", "/f", body.as_bytes()).reset
            })
            .collect();
        assert_eq!(a, d, "draws key on the tag, not the ephemeral port");
        inj.clear();
    }

    #[test]
    fn retried_identical_request_gets_a_fresh_draw() {
        let _g = test_guard();
        let inj = injector();
        inj.install(7);
        inj.add_rule(FaultRule::new("h:1", FaultKind::ErrorRate { rate: 0.5 }).tagged("t"));
        // The same logical request drawn many times walks an occurrence
        // sequence — deterministic, but not constant.
        let draws: Vec<bool> =
            (0..64).map(|_| inj.request_fault("h:1", "GET", "/x", b"same").reset).collect();
        assert!(draws.iter().any(|&v| v) && draws.iter().any(|&v| !v));
        // Reinstall resets occurrences: the sequence replays exactly.
        inj.install(7);
        inj.add_rule(FaultRule::new("h:1", FaultKind::ErrorRate { rate: 0.5 }).tagged("t"));
        let again: Vec<bool> =
            (0..64).map(|_| inj.request_fault("h:1", "GET", "/x", b"same").reset).collect();
        assert_eq!(draws, again);
        inj.clear();
    }

    #[test]
    fn source_label_scopes_rules_for_asymmetric_partitions() {
        let _g = test_guard();
        let inj = injector();
        inj.install(3);
        inj.add_rule(
            FaultRule::new("victim:1", FaultKind::BlackHole).from_src("coordinator"),
        );
        inj.set_source("coordinator");
        assert_eq!(inj.connect_fault("victim:1"), Some(ConnectFault::BlackHole));
        assert!(inj.request_fault("victim:1", "GET", "/", b"").black_hole);
        // The reverse direction (a different source) is untouched.
        inj.set_source("prober");
        assert_eq!(inj.connect_fault("victim:1"), None);
        assert!(inj.request_fault("victim:1", "GET", "/", b"").is_clean());
        inj.clear();
    }

    #[test]
    fn latency_jitter_is_bounded_and_deterministic() {
        let _g = test_guard();
        let inj = injector();
        inj.install(11);
        inj.add_rule(FaultRule::new("slow:1", FaultKind::Latency {
            base: Duration::from_millis(20),
            jitter: Duration::from_millis(10),
        }));
        let mut first = Vec::new();
        for i in 0..32 {
            let body = format!("{i}");
            let f = inj.request_fault("slow:1", "GET", "/", body.as_bytes());
            let lat = f.extra_latency.expect("latency rule always adds delay");
            assert!(
                lat >= Duration::from_millis(10) && lat <= Duration::from_millis(30),
                "base 20 ± 10: got {lat:?}"
            );
            first.push(lat);
        }
        inj.install(11);
        inj.add_rule(FaultRule::new("slow:1", FaultKind::Latency {
            base: Duration::from_millis(20),
            jitter: Duration::from_millis(10),
        }));
        for (i, want) in first.iter().enumerate() {
            let body = format!("{i}");
            let got =
                inj.request_fault("slow:1", "GET", "/", body.as_bytes()).extra_latency.unwrap();
            assert_eq!(got, *want, "jitter replays under the same seed");
        }
        inj.clear();
    }

    #[test]
    fn fnv_field_boundaries_matter() {
        assert_ne!(fnv1a(&[b"ab", b"c"]), fnv1a(&[b"a", b"bc"]));
        assert_ne!(fnv1a(&[b"", b"x"]), fnv1a(&[b"x", b""]));
    }
}
