//! YAML-subset parser for EdgeFaaS configuration files.
//!
//! The paper drives everything through YAML: resource registration (Table 1)
//! and application/DAG configuration (Table 2, source code 1 & 2). This
//! module implements the block-style subset those files use:
//!
//! * block mappings (`key: value`, nesting by indentation)
//! * block sequences (`- item`, including `- key: value` compact map entries)
//! * plain / single-quoted / double-quoted scalars
//! * `#` comments and blank lines
//! * typed scalar views (string, i64, f64, bool) resolved on access, YAML
//!   1.2-core style (`true/false`, integers, floats; everything else is a
//!   string)
//!
//! Flow style (`{a: 1}` / `[1, 2]`), anchors, tags and multi-document streams
//! are intentionally out of scope — the paper's configs never use them.

use std::collections::BTreeMap;

/// A parsed YAML node.
#[derive(Debug, Clone, PartialEq)]
pub enum Yaml {
    /// Scalar, kept as raw text; typed views resolve on access.
    Scalar(String),
    /// Block sequence.
    Seq(Vec<Yaml>),
    /// Block mapping (insertion order preserved).
    Map(Vec<(String, Yaml)>),
    /// Empty value (key with nothing after the colon and no indented block).
    Null,
}

impl Yaml {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Yaml::Scalar(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_str()?.parse().ok()
    }

    pub fn as_f64(&self) -> Option<f64> {
        self.as_str()?.parse().ok()
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self.as_str()? {
            "true" | "True" | "TRUE" => Some(true),
            "false" | "False" | "FALSE" => Some(false),
            _ => None,
        }
    }

    pub fn as_seq(&self) -> Option<&[Yaml]> {
        match self {
            Yaml::Seq(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&[(String, Yaml)]> {
        match self {
            Yaml::Map(m) => Some(m),
            _ => None,
        }
    }

    /// Look up a key in a mapping.
    pub fn get(&self, key: &str) -> Option<&Yaml> {
        match self {
            Yaml::Map(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required string field with a descriptive error.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Yaml::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing or non-scalar field `{key}`"))
    }

    /// Required integer field.
    pub fn req_i64(&self, key: &str) -> anyhow::Result<i64> {
        self.get(key)
            .and_then(Yaml::as_i64)
            .ok_or_else(|| anyhow::anyhow!("missing or non-integer field `{key}`"))
    }

    /// Map to `BTreeMap<String, String>` of scalar entries (for flat configs).
    pub fn scalar_map(&self) -> BTreeMap<String, String> {
        match self {
            Yaml::Map(m) => m
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect(),
            _ => BTreeMap::new(),
        }
    }
}

/// One significant (non-blank, non-comment) line.
#[derive(Debug)]
struct Line<'a> {
    indent: usize,
    /// Content with indentation stripped.
    text: &'a str,
    /// 1-based line number for errors.
    no: usize,
}

/// Parse a YAML document into a [`Yaml`] tree.
pub fn parse(input: &str) -> anyhow::Result<Yaml> {
    let lines: Vec<Line> = input
        .lines()
        .enumerate()
        .filter_map(|(i, raw)| {
            let trimmed_end = strip_comment(raw);
            let text = trimmed_end.trim_start();
            if text.is_empty() {
                return None;
            }
            let indent = trimmed_end.len() - text.len();
            Some(Line { indent, text: text.trim_end(), no: i + 1 })
        })
        .collect();
    if lines.is_empty() {
        return Ok(Yaml::Null);
    }
    let mut pos = 0;
    let root = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        anyhow::bail!("unexpected content at line {}", lines[pos].no);
    }
    Ok(root)
}

/// Strip a trailing `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'#' if !in_single && !in_double => {
                // A comment must be at line start or preceded by whitespace.
                if i == 0 || bytes[i - 1] == b' ' || bytes[i - 1] == b'\t' {
                    return &line[..i];
                }
            }
            _ => {}
        }
    }
    line
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> anyhow::Result<Yaml> {
    let first = &lines[*pos];
    if first.text.starts_with("- ") || first.text == "-" {
        parse_seq(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_seq(lines: &[Line], pos: &mut usize, indent: usize) -> anyhow::Result<Yaml> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            anyhow::bail!("bad indentation at line {}", line.no);
        }
        if !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let rest = line.text[1..].trim_start();
        if rest.is_empty() {
            // `-` alone: nested block on following lines.
            *pos += 1;
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Yaml::Null);
            }
        } else if let Some((key, val)) = split_key(rest) {
            // Compact map entry: `- name: x` with possible continuation keys
            // indented to the position after `- `.
            let entry_indent = line.indent + (line.text.len() - rest.len());
            let mut map = Vec::new();
            *pos += 1;
            let first_val = finish_value(val, lines, pos, entry_indent)?;
            map.push((key, first_val));
            while *pos < lines.len()
                && lines[*pos].indent == entry_indent
                && !lines[*pos].text.starts_with("- ")
                && lines[*pos].text != "-"
            {
                let l = &lines[*pos];
                let (k, v) = split_key(l.text)
                    .ok_or_else(|| anyhow::anyhow!("expected `key:` at line {}", l.no))?;
                *pos += 1;
                let val = finish_value(v, lines, pos, entry_indent)?;
                map.push((k, val));
            }
            items.push(Yaml::Map(map));
        } else {
            // Plain scalar item.
            items.push(Yaml::Scalar(unquote(rest)));
            *pos += 1;
        }
    }
    Ok(Yaml::Seq(items))
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize) -> anyhow::Result<Yaml> {
    let mut map: Vec<(String, Yaml)> = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            anyhow::bail!("bad indentation at line {}", line.no);
        }
        if line.text.starts_with("- ") || line.text == "-" {
            break;
        }
        let (key, val) = split_key(line.text)
            .ok_or_else(|| anyhow::anyhow!("expected `key: value` at line {}", line.no))?;
        if map.iter().any(|(k, _)| *k == key) {
            anyhow::bail!("duplicate key `{key}` at line {}", line.no);
        }
        *pos += 1;
        let value = finish_value(val, lines, pos, indent)?;
        map.push((key, value));
    }
    Ok(Yaml::Map(map))
}

/// After consuming a `key:` line, produce its value: an inline scalar, or a
/// nested block (map/sequence) on the following more-indented lines. A
/// sequence nested under a key may also sit at the *same* indent as the key
/// (common YAML style, used by the paper's `dag:` listing).
fn finish_value(
    inline: Option<&str>,
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
) -> anyhow::Result<Yaml> {
    if let Some(text) = inline {
        return Ok(Yaml::Scalar(unquote(text)));
    }
    if *pos < lines.len() {
        let next = &lines[*pos];
        if next.indent > indent {
            return parse_block(lines, pos, next.indent);
        }
        if next.indent == indent && (next.text.starts_with("- ") || next.text == "-") {
            return parse_seq(lines, pos, indent);
        }
    }
    Ok(Yaml::Null)
}

/// Split `key: value` / `key:`; returns `None` if the line has no key colon.
fn split_key(text: &str) -> Option<(String, Option<&str>)> {
    // Find the first `:` that is followed by space/EOL and not inside quotes.
    let bytes = text.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b':' if !in_single && !in_double => {
                if i + 1 == bytes.len() {
                    return Some((unquote(text[..i].trim()), None));
                }
                if bytes[i + 1] == b' ' {
                    let val = text[i + 1..].trim();
                    let val = if val.is_empty() { None } else { Some(val) };
                    return Some((unquote(text[..i].trim()), val));
                }
            }
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    if s.len() >= 2 {
        let b = s.as_bytes();
        if (b[0] == b'"' && b[s.len() - 1] == b'"') || (b[0] == b'\'' && b[s.len() - 1] == b'\'') {
            return s[1..s.len() - 1].to_string();
        }
    }
    s.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_map_table1() {
        // The paper's resource-registration YAML (Table 1).
        let doc = "\
name: cloud
node: 10
memory: 64GB
cpu: 32
storage: 512GB
gpunode: 8
gpu: 4
gateway: 10.107.30.249:8080
pwd: s2TsHbDfGi
prometheus: 10.107.30.112:30090
minio: 10.107.30.112:9000
minioakey: minioadmin
minioskey: minioadmin
";
        let y = parse(doc).unwrap();
        assert_eq!(y.req_str("name").unwrap(), "cloud");
        assert_eq!(y.req_i64("node").unwrap(), 10);
        assert_eq!(y.req_str("gateway").unwrap(), "10.107.30.249:8080");
        assert_eq!(y.as_map().unwrap().len(), 13);
    }

    #[test]
    fn nested_dag_source_code_2() {
        // The paper's federated-learning application YAML (source code 2).
        let doc = "\
application: federatedlearning
entrypoint: train
dag:
  - name: train
    dependencies:
    affinity:
      nodetype: iot
      nodelocation: data
    reduce: auto
  - name: firstaggregation
    dependencies: train
    affinity:
      nodetype: edge
      nodelocation: function
    reduce: auto
  - name: secondaggregation
    dependencies: firstaggregation
    affinity:
      nodetype: cloud
      nodelocation: function
    reduce: 1
";
        let y = parse(doc).unwrap();
        assert_eq!(y.req_str("application").unwrap(), "federatedlearning");
        let dag = y.get("dag").unwrap().as_seq().unwrap();
        assert_eq!(dag.len(), 3);
        assert_eq!(dag[0].req_str("name").unwrap(), "train");
        assert_eq!(dag[0].get("dependencies"), Some(&Yaml::Null));
        assert_eq!(dag[0].get("affinity").unwrap().req_str("nodetype").unwrap(), "iot");
        assert_eq!(dag[2].req_str("reduce").unwrap(), "1");
        assert_eq!(dag[2].get("reduce").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn seq_at_same_indent_as_key() {
        // `dag:` followed by `- ` items at the same indent (paper style).
        let doc = "\
dag:
- name: a
- name: b
";
        let y = parse(doc).unwrap();
        let dag = y.get("dag").unwrap().as_seq().unwrap();
        assert_eq!(dag.len(), 2);
        assert_eq!(dag[1].req_str("name").unwrap(), "b");
    }

    #[test]
    fn comments_and_blanks() {
        let doc = "\
# resource file
name: edge  # inline comment

cpu: 32
note: 'a # not comment'
";
        let y = parse(doc).unwrap();
        assert_eq!(y.req_str("name").unwrap(), "edge");
        assert_eq!(y.req_i64("cpu").unwrap(), 32);
        assert_eq!(y.req_str("note").unwrap(), "a # not comment");
    }

    #[test]
    fn quoted_scalars() {
        let doc = "a: \"x: y\"\nb: 'hello world'\n";
        let y = parse(doc).unwrap();
        assert_eq!(y.req_str("a").unwrap(), "x: y");
        assert_eq!(y.req_str("b").unwrap(), "hello world");
    }

    #[test]
    fn plain_scalar_sequence() {
        let doc = "deps:\n  - a\n  - b\n  - c\n";
        let y = parse(doc).unwrap();
        let deps = y.get("deps").unwrap().as_seq().unwrap();
        let names: Vec<_> = deps.iter().map(|d| d.as_str().unwrap()).collect();
        assert_eq!(names, ["a", "b", "c"]);
    }

    #[test]
    fn typed_views() {
        let doc = "i: 42\nf: 2.5\nt: true\nf2: false\ns: hello\n";
        let y = parse(doc).unwrap();
        assert_eq!(y.get("i").unwrap().as_i64(), Some(42));
        assert_eq!(y.get("f").unwrap().as_f64(), Some(2.5));
        assert_eq!(y.get("t").unwrap().as_bool(), Some(true));
        assert_eq!(y.get("f2").unwrap().as_bool(), Some(false));
        assert_eq!(y.get("s").unwrap().as_bool(), None);
    }

    #[test]
    fn rejects_duplicate_keys() {
        assert!(parse("a: 1\na: 2\n").is_err());
    }

    #[test]
    fn rejects_bad_indent() {
        assert!(parse("a: 1\n   b: 2\n c: 3\n").is_err());
    }

    #[test]
    fn deep_nesting() {
        let doc = "\
a:
  b:
    c:
      d: leaf
";
        let y = parse(doc).unwrap();
        let leaf = y.get("a").unwrap().get("b").unwrap().get("c").unwrap().req_str("d").unwrap();
        assert_eq!(leaf, "leaf");
    }

    #[test]
    fn empty_doc_is_null() {
        assert_eq!(parse("\n# only a comment\n").unwrap(), Yaml::Null);
    }

    #[test]
    fn video_pipeline_yaml_source_code_1() {
        let doc = "\
application: videopipeline
entrypoint: video-generator
dag:
  - name: video-generator
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: video-processing
    dependencies: video-generator
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: auto
  - name: face-recognition
    dependencies: face-extraction
    affinity:
      nodetype: cloud
      affinitytype: function
    reduce: auto
";
        let y = parse(doc).unwrap();
        let dag = y.get("dag").unwrap().as_seq().unwrap();
        assert_eq!(dag.len(), 3);
        assert_eq!(
            dag[0].get("affinity").unwrap().req_str("affinitytype").unwrap(),
            "data"
        );
    }
}
