//! Fixed-size worker pool over `std::thread` + channels.
//!
//! Backs the HTTP gateways (one pool per listener) and the cluster
//! substrate's sandbox executors. No tokio in the offline build — the
//! coordinator's request path is thread-per-pool-slot, which for the scale
//! of the paper's testbed (tens of concurrent invocations) is ample.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Error returned by [`ThreadPool::execute`] when the pool can no longer
/// accept work (explicitly shut down, or every worker died).
#[derive(Debug, PartialEq, Eq)]
pub struct PoolShutDown;

impl std::fmt::Display for PoolShutDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("thread pool is shut down")
    }
}

impl std::error::Error for PoolShutDown {}

/// A fixed pool of worker threads executing queued jobs.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (must be > 0).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0, "ThreadPool::new(0)");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(sender) }
    }

    /// Queue a job. Returns [`PoolShutDown`] (instead of panicking) if the
    /// pool was shut down or its workers are gone.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), PoolShutDown> {
        match &self.sender {
            None => Err(PoolShutDown),
            Some(s) => s.send(Box::new(f)).map_err(|_| PoolShutDown),
        }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Drain the queue and join every worker. Idempotent; called by `Drop`.
    /// Jobs already queued still run to completion before this returns.
    pub fn shutdown(&mut self) {
        // Close the channel, then join every worker.
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Run `f` over `items` with up to `width` scoped threads, collecting results
/// in input order. Used by fan-out paths (multi-resource deploys, FedAvg
/// rounds) where the item count is small and bounded.
pub fn scoped_map<T, R, F>(items: Vec<T>, width: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    assert!(width > 0);
    let n = items.len();
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let results_mx = Mutex::new(&mut results);
    thread::scope(|s| {
        for _ in 0..width.min(n.max(1)) {
            s.spawn(|| loop {
                let next = { work.lock().unwrap().next() };
                match next {
                    Some((i, item)) => {
                        let r = f(item);
                        results_mx.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    results.into_iter().map(|r| r.expect("worker finished")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = ThreadPool::new(4);
        let start = std::time::Instant::now();
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                thread::sleep(Duration::from_millis(50));
                d.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        drop(pool);
        assert_eq!(done.load(Ordering::SeqCst), 4);
        // 4 × 50ms jobs on 4 workers should take ~50ms, not 200ms.
        assert!(start.elapsed() < Duration::from_millis(180));
    }

    #[test]
    fn shutdown_drains_queued_jobs_then_rejects_new_ones() {
        let mut pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                thread::sleep(Duration::from_millis(2));
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.shutdown();
        // Drop/shutdown semantics: every queued job ran before the join
        // returned, and the workers are gone.
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        assert_eq!(pool.size(), 0);
        // Execute after shutdown is an error, not a panic.
        assert_eq!(pool.execute(|| {}), Err(PoolShutDown));
        // Idempotent.
        pool.shutdown();
        assert_eq!(pool.execute(|| {}), Err(PoolShutDown));
    }

    #[test]
    fn scoped_map_preserves_order() {
        let out = scoped_map((0..32).collect::<Vec<_>>(), 8, |x| x * x);
        assert_eq!(out, (0..32).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty() {
        let out: Vec<i32> = scoped_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
