//! Performance model for the paper's evaluation (§5.1).
//!
//! The paper measured its figures on a physical testbed; we reproduce them
//! from a calibrated analytic model layered on the [`crate::simnet`]
//! substrate. [`calib`] holds the per-stage data sizes and compute
//! latencies fitted to the paper's reported anchor points; [`analytic`]
//! derives every figure (6, 8, 9) from those plus the topology, so the
//! *shape* of each result — who wins, by what factor, where the crossover
//! falls — is a computation, not a transcription.

pub mod analytic;
pub mod calib;

pub use calib::{PaperCalib, Stage, STAGES};
