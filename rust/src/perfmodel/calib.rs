//! Calibrated constants for the video-analytics evaluation.
//!
//! Anchor points taken from the paper's text:
//! * Fig. 5/6: the 30 s video is 92 MB; uploading it to the edge takes
//!   8.5 s and to the cloud ~92.7 s.
//! * Fig. 7: face detection takes 0.433 s on edge vs 0.113 s on cloud GPU.
//! * Fig. 8: end-to-end (from video-processing) cloud-only 96.7 s,
//!   edge-only 12.1 s.
//! * Fig. 9: best partition at motion-detection, 11.5 s; improvements
//!   7.4x over cloud-only and ~5% over edge-only.
//!
//! The remaining sizes/latencies are fitted so all anchors hold
//! simultaneously under the transfer model `t = rtt + overhead + B/bw`
//! (see the module tests, which assert each anchor).

/// The six pipeline stages (§4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    VideoGenerator,
    VideoProcessing,
    MotionDetection,
    FaceDetection,
    FaceExtraction,
    FaceRecognition,
}

pub const STAGES: [Stage; 6] = [
    Stage::VideoGenerator,
    Stage::VideoProcessing,
    Stage::MotionDetection,
    Stage::FaceDetection,
    Stage::FaceExtraction,
    Stage::FaceRecognition,
];

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::VideoGenerator => "video-generator",
            Stage::VideoProcessing => "video-processing",
            Stage::MotionDetection => "motion-detection",
            Stage::FaceDetection => "face-detection",
            Stage::FaceExtraction => "face-extraction",
            Stage::FaceRecognition => "face-recognition",
        }
    }

    pub fn index(&self) -> usize {
        STAGES.iter().position(|s| s == self).unwrap()
    }
}

/// The calibrated evaluation model.
#[derive(Debug, Clone)]
pub struct PaperCalib {
    /// Output data size per stage for the 30 s window, bytes (Fig. 5).
    pub out_bytes: [u64; 6],
    /// Compute latency per stage on the edge tier, seconds (Fig. 7).
    pub edge_compute: [f64; 6],
    /// Compute latency per stage on the cloud tier (GPU where the paper
    /// used it), seconds (Fig. 7).
    pub cloud_compute: [f64; 6],
    /// IoT->edge LAN bandwidth, bytes/s.
    pub lan_bw: f64,
    /// Edge/IoT->cloud uplink bandwidth, bytes/s.
    pub wan_bw: f64,
    /// IoT->edge RTT, seconds (set 1 of Fig. 4).
    pub lan_rtt: f64,
    /// Edge->cloud RTT, seconds (set 1 of Fig. 4).
    pub wan_rtt: f64,
}


impl Default for PaperCalib {
    fn default() -> Self {
        PaperCalib {
            out_bytes: [
                92_000_000, // 30 s of 1080p video (Fig. 5's 92 MB)
                30_000_000, // zipped GoPs: "also generated at a large size"
                550_000,    // only the motion-bearing pictures survive
                300_000,    // pictures containing faces
                120_000,    // extracted face features
                50_000,     // identity-tagged pictures
            ],
            edge_compute: [0.0, 1.300, 0.390, 0.433, 0.450, 1.027],
            cloud_compute: [0.0, 0.950, 0.220, 0.113, 0.160, 0.470],
            lan_bw: 92_000_000.0 / 8.5,  // 92 MB in 8.5 s (Fig. 6)
            wan_bw: 7.765e6 / 8.0,       // fitted: cloud-only e2e = 96.7 s
            lan_rtt: 0.0057,
            wan_rtt: 0.0434,
        }
    }
}

impl PaperCalib {
    /// Transfer time of `bytes` from the IoT/edge LAN to the edge tier.
    pub fn to_edge(&self, bytes: u64) -> f64 {
        self.lan_rtt / 2.0 + bytes as f64 / self.lan_bw
    }

    /// Transfer time of `bytes` up to the cloud tier.
    pub fn to_cloud(&self, bytes: u64) -> f64 {
        (self.lan_rtt + self.wan_rtt) / 2.0 + bytes as f64 / self.wan_bw
    }

    /// Compute latency of a stage on a tier ("edge" or "cloud").
    pub fn compute(&self, stage: Stage, on_cloud: bool) -> f64 {
        if on_cloud {
            self.cloud_compute[stage.index()]
        } else {
            self.edge_compute[stage.index()]
        }
    }

    /// IoT-tier compute estimate (Fig. 7's third series): the Pi's
    /// Cortex-A72 runs the CPU stages ~12x slower than the edge Xeon.
    pub fn iot_compute(&self, stage: Stage) -> f64 {
        self.edge_compute[stage.index()] / 0.08
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::analytic;

    #[test]
    fn anchor_video_upload_times() {
        let c = PaperCalib::default();
        // Fig. 6: 92 MB to edge 8.5 s, to cloud ≈ 92.7-95 s.
        let e = c.to_edge(c.out_bytes[0]);
        assert!((e - 8.5).abs() < 0.1, "to edge: {e}");
        let w = c.to_cloud(c.out_bytes[0]);
        assert!((w - 94.8).abs() < 1.0, "to cloud: {w}");
    }

    #[test]
    fn anchor_face_detection_speedup() {
        let c = PaperCalib::default();
        // Fig. 7: 0.433 s edge vs 0.113 s cloud GPU.
        assert_eq!(c.compute(Stage::FaceDetection, false), 0.433);
        assert_eq!(c.compute(Stage::FaceDetection, true), 0.113);
    }

    #[test]
    fn anchor_fig8_end_to_end() {
        let c = PaperCalib::default();
        let cloud_only = analytic::end_to_end(&c, 0);
        let edge_only = analytic::end_to_end(&c, 5);
        assert!((cloud_only - 96.7).abs() < 0.5, "cloud-only: {cloud_only}");
        assert!((edge_only - 12.1).abs() < 0.15, "edge-only: {edge_only}");
    }

    #[test]
    fn anchor_fig9_best_partition() {
        let c = PaperCalib::default();
        let (best_idx, best) = analytic::best_partition(&c);
        assert_eq!(STAGES[best_idx], Stage::MotionDetection, "best at motion detection");
        assert!((best - 11.5).abs() < 0.2, "best: {best}");
        // Headline improvements.
        let cloud_only = analytic::end_to_end(&c, 0);
        let edge_only = analytic::end_to_end(&c, 5);
        let x = (cloud_only - best) / best;
        assert!((x - 7.4).abs() < 0.3, "7.4x over cloud-only, got {x:.2}");
        let pct = (edge_only - best) / best * 100.0;
        assert!((2.0..10.0).contains(&pct), "~5% over edge-only, got {pct:.1}%");
    }

    #[test]
    fn sizes_monotone_after_processing() {
        // Fig. 5's shape: big, big, then small and shrinking.
        let c = PaperCalib::default();
        assert!(c.out_bytes[0] > c.out_bytes[1]);
        for i in 1..5 {
            assert!(c.out_bytes[i] > c.out_bytes[i + 1], "stage {i}");
        }
        assert!(c.out_bytes[1] > 10 * c.out_bytes[2], "processing -> motion cliff");
    }
}
