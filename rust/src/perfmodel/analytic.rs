//! Analytic derivations of Figs. 6, 8 and 9 from the calibrated model.
//!
//! Semantics of a *partition point* follow §5.1.2: the video generator is
//! always on IoT; stages up to and including the partition stage run on the
//! edge tier; everything after runs on the cloud. Partition at
//! `video-generator` (index 0) is therefore the cloud-only solution and at
//! `face-recognition` (index 5) the edge-only solution.

use super::calib::{PaperCalib, STAGES};

/// Fig. 6 row: upload latency of stage `i`'s output to (edge, cloud).
pub fn comm_latency(c: &PaperCalib, stage_idx: usize) -> (f64, f64) {
    let bytes = c.out_bytes[stage_idx];
    (c.to_edge(bytes), c.to_cloud(bytes))
}

/// End-to-end latency (from video-processing onward, matching Fig. 8's
/// measurement window) for a given partition index in 0..=5.
pub fn end_to_end(c: &PaperCalib, partition: usize) -> f64 {
    assert!(partition < STAGES.len());
    let mut t = 0.0;
    // The generator's 92 MB output must reach the first compute tier.
    if partition == 0 {
        // Everything on cloud: the video goes straight up.
        t += c.to_cloud(c.out_bytes[0]);
    } else {
        t += c.to_edge(c.out_bytes[0]);
    }
    // Stages 1..=5 run on edge (i <= partition) or cloud (i > partition).
    for i in 1..STAGES.len() {
        let on_cloud = i > partition;
        t += c.compute(STAGES[i], on_cloud);
        // Crossing the partition boundary ships stage `partition`'s output.
        if i > 0 && i == partition + 1 && partition >= 1 {
            t += c.to_cloud(c.out_bytes[partition]);
        }
    }
    t
}

/// Fig. 9: the whole partition sweep.
pub fn partition_sweep(c: &PaperCalib) -> Vec<(usize, f64)> {
    (0..STAGES.len()).map(|p| (p, end_to_end(c, p))).collect()
}

/// The best partition point and its latency.
pub fn best_partition(c: &PaperCalib) -> (usize, f64) {
    partition_sweep(c)
        .into_iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap()
}

/// Breakdown of one partition's end-to-end latency into
/// (ingest transfer, edge compute, cross transfer, cloud compute) — the
/// stacked bars of Fig. 9.
pub fn breakdown(c: &PaperCalib, partition: usize) -> (f64, f64, f64, f64) {
    let ingest = if partition == 0 {
        c.to_cloud(c.out_bytes[0])
    } else {
        c.to_edge(c.out_bytes[0])
    };
    let mut edge = 0.0;
    let mut cloud = 0.0;
    for i in 1..STAGES.len() {
        if i <= partition {
            edge += c.compute(STAGES[i], false);
        } else {
            cloud += c.compute(STAGES[i], true);
        }
    }
    let cross = if (1..5).contains(&partition) { c.to_cloud(c.out_bytes[partition]) } else { 0.0 };
    (ingest, edge, cross, cloud)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_u_shaped() {
        // Fig. 9's shape: huge at the data-heavy early partitions, a basin
        // in the middle, slightly rising at the pure-edge end.
        let c = PaperCalib::default();
        let sweep = partition_sweep(&c);
        assert!(sweep[0].1 > 90.0, "cloud-only dominated by the 92 MB upload");
        assert!(sweep[1].1 > 30.0, "partition at processing still ships 30 MB");
        assert!(sweep[2].1 < 12.0, "after motion detection the data is small");
        let best = best_partition(&c);
        assert_eq!(best.0, 2);
        // Every partition after the best is within a second (flat basin).
        for p in 3..6 {
            assert!(sweep[p].1 - best.1 < 1.0, "p={p}: {}", sweep[p].1);
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let c = PaperCalib::default();
        for p in 0..6 {
            let (a, b, x, d) = breakdown(&c, p);
            let total = end_to_end(&c, p);
            assert!((a + b + x + d - total).abs() < 1e-9, "p={p}");
        }
    }

    #[test]
    fn comm_latency_matches_fig6_ordering() {
        let c = PaperCalib::default();
        for i in 0..6 {
            let (e, w) = comm_latency(&c, i);
            assert!(w > e, "cloud upload always slower (stage {i})");
        }
        let (e0, w0) = comm_latency(&c, 0);
        assert!((e0 - 8.5).abs() < 0.1);
        assert!(w0 > 90.0);
        let (_, w5) = comm_latency(&c, 5);
        assert!(w5 < 1.0, "late-stage outputs are cheap to ship");
    }
}
