//! EdgeFaaS leader entrypoint.
//!
//! Subcommands (hand-rolled arg parsing; clap is unavailable offline):
//!
//! ```text
//! edgefaas serve [--port P]               run the unified gateway over the
//!                                         Fig. 4 testbed (REST control plane)
//! edgefaas plan <app.yaml> [fn=rid,rid..] parse + schedule an application
//!                                         YAML, print the placement plan
//! edgefaas figures                        print the paper-figure summaries
//! edgefaas artifacts                      list the AOT artifact manifest
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::coordinator::gateway::EdgeFaasGateway;
use edgefaas::perfmodel::{analytic, PaperCalib, STAGES};
use edgefaas::simnet::RealClock;
use edgefaas::testbed::{artifacts_dir, paper_testbed};

fn usage() -> ! {
    eprintln!(
        "usage: edgefaas <serve [--port P]|plan <app.yaml> [fn=rids..]|figures|artifacts>"
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    edgefaas::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("serve") => serve(&args[1..]),
        Some("plan") => plan(&args[1..]),
        Some("figures") => figures(),
        Some("artifacts") => artifacts(),
        _ => usage(),
    }
}

fn serve(args: &[String]) -> anyhow::Result<()> {
    let mut port = 7070u16;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--port" => {
                port = args.get(i + 1).and_then(|p| p.parse().ok()).unwrap_or_else(|| usage());
                i += 2;
            }
            _ => usage(),
        }
    }
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let server = {
        let gw = Arc::new(EdgeFaasGateway::new(Arc::clone(&bed.faas)));
        edgefaas::util::http::Server::bind(port, 8, gw as Arc<dyn edgefaas::util::http::Handler>)?
    };
    println!("EdgeFaaS gateway on http://{}", server.addr());
    println!("resources: {:?} (8 IoT + 2 edge + 1 cloud, Fig. 4 testbed)", bed.faas.resource_ids());
    println!("try: curl http://{}/resources", server.addr());
    // Serve until interrupted.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn plan(args: &[String]) -> anyhow::Result<()> {
    let path = args.first().unwrap_or_else(|| usage());
    let yaml = std::fs::read_to_string(path)?;
    let mut data: HashMap<String, Vec<u32>> = HashMap::new();
    for spec in &args[1..] {
        let (f, rids) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bad data anchor `{spec}` (want fn=rid,rid)"))?;
        data.insert(
            f.to_string(),
            rids.split(',').filter_map(|r| r.parse().ok()).collect(),
        );
    }
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let plan = bed.faas.configure_application(&yaml, &data)?;
    println!("placement plan over the Fig. 4 testbed:");
    let mut names: Vec<&String> = plan.keys().collect();
    names.sort();
    for f in names {
        let tiers: Vec<&str> = plan[f]
            .iter()
            .map(|&r| bed.faas.resource(r).map(|x| x.spec.tier.name()).unwrap_or("?"))
            .collect();
        println!("  {f:<20} -> {:?} ({})", plan[f], tiers.join(","));
    }
    Ok(())
}

fn figures() -> anyhow::Result<()> {
    let calib = PaperCalib::default();
    println!(
        "Fig. 8 end-to-end: cloud-only {:.1} s, edge-only {:.1} s",
        analytic::end_to_end(&calib, 0),
        analytic::end_to_end(&calib, 5)
    );
    println!("\nFig. 9 partition sweep:");
    for (p, t) in analytic::partition_sweep(&calib) {
        println!("  {:<18} {t:>7.2} s", STAGES[p].name());
    }
    let (best, t) = analytic::best_partition(&calib);
    println!(
        "best: {} at {t:.2} s ({:.1}x vs cloud-only)",
        STAGES[best].name(),
        (analytic::end_to_end(&calib, 0) - t) / t
    );
    println!("\n(full tables: `cargo bench`)");
    Ok(())
}

fn artifacts() -> anyhow::Result<()> {
    let manifest = edgefaas::runtime::Manifest::load(artifacts_dir())?;
    println!("artifact manifest (fingerprint {}):", &manifest.fingerprint[..12]);
    for (name, e) in &manifest.entries {
        let ins: Vec<String> = e.inputs.iter().map(|s| s.describe()).collect();
        let outs: Vec<String> = e.outputs.iter().map(|s| s.describe()).collect();
        println!("  {name:<18} ({}) -> ({})", ins.join(", "), outs.join(", "));
    }
    Ok(())
}
