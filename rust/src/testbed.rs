//! The paper's Fig. 4 testbed, in-process.
//!
//! Two sets of {4 Raspberry Pis + 1 edge server} plus a 10-node GPU cloud
//! cluster, with the measured RTTs (5.7 / 43.4 ms for set 1, 0.6 / 4.7 ms
//! for set 2) and calibrated bandwidths. Every resource runs the full
//! substrate stack — FaaS backend, object store, metrics — behind a
//! [`LocalHandle`], registered with the coordinator exactly as a remote
//! gateway would be. Used by the examples, the benches and the integration
//! tests.
//!
//! [`scale_testbed`] builds the same stack in a parameterized star-of-stars
//! shape (cells of IoT boxes behind edge hubs, one cloud) for the scale
//! harness, where thousands of simulated devices multiplex onto a
//! bounded registered fleet.

use std::sync::Arc;

use crate::backup::DurableKv;
use crate::cluster::faas::{Executor, FaasBackend, NativeExecutor};
use crate::cluster::spec::ResourceSpec;
use crate::coordinator::handle::{LocalHandle, ResourceHandle};
use crate::coordinator::resource::{EdgeFaaS, ResourceId};
use crate::objstore::ObjectStore;
use crate::simnet::topology::mbps;
use crate::simnet::{Clock, Tier, Topology};

/// A running paper testbed.
pub struct TestBed {
    pub faas: Arc<EdgeFaaS>,
    /// Shared executor: register handler images here.
    pub executor: Arc<NativeExecutor>,
    /// The 8 Raspberry Pis (set 1 = indices 0..4, set 2 = 4..8).
    pub iot: Vec<ResourceId>,
    /// The two edge clusters.
    pub edges: Vec<ResourceId>,
    /// The cloud cluster.
    pub cloud: ResourceId,
}

impl TestBed {
    /// Every resource id, IoT first, then edges, then cloud.
    pub fn all_resources(&self) -> Vec<ResourceId> {
        let mut v = self.iot.clone();
        v.extend(&self.edges);
        v.push(self.cloud);
        v
    }
}

/// Build the Fig. 4 topology graph alone.
pub fn paper_topology() -> (Topology, Vec<usize>, Vec<usize>, usize) {
    let mut topo = Topology::new();
    let mut pi_nodes = Vec::new();
    for set in 0..2 {
        for i in 0..4 {
            pi_nodes.push(topo.add_node(format!("pi-{set}-{i}"), Tier::Iot));
        }
    }
    let e0 = topo.add_node("edge-0", Tier::Edge);
    let e1 = topo.add_node("edge-1", Tier::Edge);
    let cl = topo.add_node("cloud", Tier::Cloud);
    for i in 0..4 {
        // LAN bandwidth calibrated from Fig. 6: 92 MB to the edge in 8.5 s.
        topo.add_link(pi_nodes[i], e0, 0.0057, mbps(86.6));
        topo.add_link(pi_nodes[4 + i], e1, 0.0006, mbps(86.6));
    }
    // Uplink bandwidth calibrated so the paper's Fig. 6/8 anchors hold
    // (92 MB to the cloud in ~95 s).
    topo.add_link(e0, cl, 0.0434, mbps(7.765));
    topo.add_link(e1, cl, 0.0047, mbps(7.765));
    (topo, pi_nodes, vec![e0, e1], cl)
}

/// Build the full in-process testbed against a clock.
pub fn paper_testbed(clock: Arc<dyn Clock>) -> TestBed {
    let (topo, pi_nodes, edge_nodes, cloud_node) = paper_topology();
    let executor = Arc::new(NativeExecutor::new());
    let faas = EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), Arc::clone(&clock));

    let mk_handle = |spec: &ResourceSpec| -> Arc<dyn ResourceHandle> {
        let backend = Arc::new(FaasBackend::new(
            spec.clone(),
            Arc::clone(&executor) as Arc<dyn Executor>,
            Arc::clone(&clock),
        ));
        let store = Arc::new(ObjectStore::new(
            spec.storage * spec.nodes as u64,
            &spec.minio_access_key,
            &spec.minio_secret_key,
        ));
        Arc::new(LocalHandle::new(backend, store))
    };

    let mut iot = Vec::new();
    for (i, &node) in pi_nodes.iter().enumerate() {
        let spec = ResourceSpec::paper_iot(&format!("pi{i}:8080"));
        let h = mk_handle(&spec);
        iot.push(faas.register(spec, h, node).unwrap());
    }
    let mut edges = Vec::new();
    for (i, node) in edge_nodes.into_iter().enumerate() {
        let spec = ResourceSpec::paper_edge(&format!("edge{i}:8080"));
        let h = mk_handle(&spec);
        edges.push(faas.register(spec, h, node).unwrap());
    }
    let spec = ResourceSpec::paper_cloud("cloud:8080");
    let h = mk_handle(&spec);
    let cloud = faas.register(spec, h, cloud_node).unwrap();

    TestBed { faas: Arc::new(faas), executor, iot, edges, cloud }
}

/// A running scale-harness fleet (see [`scale_testbed`]).
pub struct ScaleBed {
    pub faas: Arc<EdgeFaaS>,
    /// Shared executor: register handler images here.
    pub executor: Arc<NativeExecutor>,
    /// Device-hosting IoT boxes, grouped per cell: `cell_boxes[c]` are the
    /// registered resources behind cell `c`'s hub. Simulated devices are
    /// multiplexed onto these (device `d` submits through cell
    /// `d % cells`), so the *device* count scales independently of the
    /// *registered-resource* count — the latter is bounded by the
    /// monitoring snapshot's dense latency matrix.
    pub cell_boxes: Vec<Vec<ResourceId>>,
    /// One edge hub per cell.
    pub hubs: Vec<ResourceId>,
    pub cloud: ResourceId,
}

impl ScaleBed {
    /// Every resource id: boxes cell by cell, then hubs, then cloud.
    pub fn all_resources(&self) -> Vec<ResourceId> {
        let mut v: Vec<ResourceId> = self.cell_boxes.iter().flatten().copied().collect();
        v.extend(&self.hubs);
        v.push(self.cloud);
        v
    }
}

/// Build the scale-harness topology graph alone: `cells` edge cells, each
/// one hub fronting `boxes_per_cell` IoT boxes (2 ms LAN), hubs uplinked to
/// one cloud (30 ms WAN). Deterministic: repeated calls produce identical
/// node ids, which is what lets [`federated_testbed`] give every
/// coordinator its own copy of the same graph.
pub fn scale_topology(
    cells: usize,
    boxes_per_cell: usize,
) -> (Topology, Vec<Vec<usize>>, Vec<usize>, usize) {
    let mut topo = Topology::new();
    let mut box_nodes = Vec::new();
    let mut hub_nodes = Vec::new();
    for c in 0..cells {
        let hub = topo.add_node(format!("hub-{c}"), Tier::Edge);
        let mut boxes = Vec::new();
        for b in 0..boxes_per_cell {
            let n = topo.add_node(format!("box-{c}-{b}"), Tier::Iot);
            topo.add_link(n, hub, 0.002, mbps(100.0));
            boxes.push(n);
        }
        box_nodes.push(boxes);
        hub_nodes.push(hub);
    }
    let cloud_node = topo.add_node("cloud", Tier::Cloud);
    for &hub in &hub_nodes {
        topo.add_link(hub, cloud_node, 0.03, mbps(50.0));
    }
    (topo, box_nodes, hub_nodes, cloud_node)
}

/// Build the scale-harness fleet: the [`scale_topology`] graph with the
/// full substrate stack on every node. The registered fleet is
/// `cells * boxes_per_cell + cells + 1` resources; populations of any
/// device count run on top of it (`workloads::population`).
pub fn scale_testbed(clock: Arc<dyn Clock>, cells: usize, boxes_per_cell: usize) -> ScaleBed {
    assert!(cells > 0 && boxes_per_cell > 0, "scale_testbed needs a non-empty fleet");
    let executor = Arc::new(NativeExecutor::new());
    let (topo, box_nodes, hub_nodes, cloud_node) = scale_topology(cells, boxes_per_cell);

    let faas = EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), Arc::clone(&clock));
    let mk_handle = |spec: &ResourceSpec| -> Arc<dyn ResourceHandle> {
        let backend = Arc::new(FaasBackend::new(
            spec.clone(),
            Arc::clone(&executor) as Arc<dyn Executor>,
            Arc::clone(&clock),
        ));
        let store = Arc::new(ObjectStore::new(
            spec.storage * spec.nodes as u64,
            &spec.minio_access_key,
            &spec.minio_secret_key,
        ));
        Arc::new(LocalHandle::new(backend, store))
    };

    let mut cell_boxes = Vec::new();
    for (c, boxes) in box_nodes.into_iter().enumerate() {
        let mut ids = Vec::new();
        for (b, node) in boxes.into_iter().enumerate() {
            let spec = ResourceSpec::paper_iot(&format!("box{c}x{b}:8080"));
            let h = mk_handle(&spec);
            ids.push(faas.register(spec, h, node).unwrap());
        }
        cell_boxes.push(ids);
    }
    let mut hubs = Vec::new();
    for (c, node) in hub_nodes.into_iter().enumerate() {
        let spec = ResourceSpec::paper_edge(&format!("hub{c}:8080"));
        let h = mk_handle(&spec);
        hubs.push(faas.register(spec, h, node).unwrap());
    }
    let spec = ResourceSpec::paper_cloud("cloud:8080");
    let h = mk_handle(&spec);
    let cloud = faas.register(spec, h, cloud_node).unwrap();

    ScaleBed { faas: Arc::new(faas), executor, cell_boxes, hubs, cloud }
}

/// A federated fleet: `n` coordinators over ONE shared substrate (see
/// [`federated_testbed`]).
pub struct FederatedBed {
    /// The coordinators, in member-id order (`coordinators[k]` is
    /// federation member `k`). Every coordinator sees the same resource
    /// ids for the same physical boxes.
    pub coordinators: Vec<Arc<EdgeFaaS>>,
    /// Shared executor: register handler images here (once — the backends
    /// are shared, so handlers serve every coordinator).
    pub executor: Arc<NativeExecutor>,
    /// Registered boxes per cell, same ids on every coordinator.
    pub cell_boxes: Vec<Vec<ResourceId>>,
    pub hubs: Vec<ResourceId>,
    pub cloud: ResourceId,
}

impl FederatedBed {
    /// Every resource id: boxes cell by cell, then hubs, then cloud.
    pub fn all_resources(&self) -> Vec<ResourceId> {
        let mut v: Vec<ResourceId> = self.cell_boxes.iter().flatten().copied().collect();
        v.extend(&self.hubs);
        v.push(self.cloud);
        v
    }
}

/// Build `n` coordinators jointly serving one [`scale_topology`] fleet —
/// the in-process bed for the federation plane.
///
/// The *substrate* is built once: one [`FaasBackend`] + object store per
/// resource, shared by every coordinator. Each coordinator then registers
/// the same handles in the same order against its own copy of the topology
/// graph, so resource ids are identical fleet-wide — exactly the invariant
/// [`crate::coordinator::federation`] assumes. Sharing the backends also
/// means the attempt-id dedup cache is per *box*, not per coordinator:
/// a stolen instance retried through a different coordinator still hits
/// the same cache, which is what makes work stealing at-most-once.
pub fn federated_testbed(
    clock: Arc<dyn Clock>,
    n: usize,
    cells: usize,
    boxes_per_cell: usize,
) -> FederatedBed {
    assert!(n > 0, "federated_testbed needs at least one coordinator");
    assert!(cells > 0 && boxes_per_cell > 0, "federated_testbed needs a non-empty fleet");
    let executor = Arc::new(NativeExecutor::new());
    let mk_handle = |spec: &ResourceSpec| -> Arc<dyn ResourceHandle> {
        let backend = Arc::new(FaasBackend::new(
            spec.clone(),
            Arc::clone(&executor) as Arc<dyn Executor>,
            Arc::clone(&clock),
        ));
        let store = Arc::new(ObjectStore::new(
            spec.storage * spec.nodes as u64,
            &spec.minio_access_key,
            &spec.minio_secret_key,
        ));
        Arc::new(LocalHandle::new(backend, store))
    };

    // One substrate stack per resource, in registration order.
    let mut box_handles: Vec<Vec<(ResourceSpec, Arc<dyn ResourceHandle>)>> = Vec::new();
    for c in 0..cells {
        let mut row = Vec::new();
        for b in 0..boxes_per_cell {
            let spec = ResourceSpec::paper_iot(&format!("box{c}x{b}:8080"));
            let h = mk_handle(&spec);
            row.push((spec, h));
        }
        box_handles.push(row);
    }
    let mut hub_handles: Vec<(ResourceSpec, Arc<dyn ResourceHandle>)> = Vec::new();
    for c in 0..cells {
        let spec = ResourceSpec::paper_edge(&format!("hub{c}:8080"));
        let h = mk_handle(&spec);
        hub_handles.push((spec, h));
    }
    let cloud_spec = ResourceSpec::paper_cloud("cloud:8080");
    let cloud_handle = mk_handle(&cloud_spec);

    let mut coordinators = Vec::new();
    let mut cell_boxes: Vec<Vec<ResourceId>> = Vec::new();
    let mut hubs: Vec<ResourceId> = Vec::new();
    let mut cloud = ResourceId::default();
    for k in 0..n {
        let (topo, box_nodes, hub_nodes, cloud_node) = scale_topology(cells, boxes_per_cell);
        let faas = EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), Arc::clone(&clock));
        let mut my_cells = Vec::new();
        for (c, row) in box_handles.iter().enumerate() {
            let mut ids = Vec::new();
            for (b, (spec, h)) in row.iter().enumerate() {
                ids.push(faas.register(spec.clone(), Arc::clone(h), box_nodes[c][b]).unwrap());
            }
            my_cells.push(ids);
        }
        let mut my_hubs = Vec::new();
        for (c, (spec, h)) in hub_handles.iter().enumerate() {
            my_hubs.push(faas.register(spec.clone(), Arc::clone(h), hub_nodes[c]).unwrap());
        }
        let my_cloud =
            faas.register(cloud_spec.clone(), Arc::clone(&cloud_handle), cloud_node).unwrap();
        if k == 0 {
            cell_boxes = my_cells;
            hubs = my_hubs;
            cloud = my_cloud;
        } else {
            debug_assert_eq!(cell_boxes, my_cells, "resource ids must match across members");
            debug_assert_eq!(hubs, my_hubs);
            debug_assert_eq!(cloud, my_cloud);
        }
        coordinators.push(Arc::new(faas));
    }

    FederatedBed { coordinators, executor, cell_boxes, hubs, cloud }
}

/// Locate the AOT artifact directory (`artifacts/` at the crate root).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
