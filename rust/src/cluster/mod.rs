//! Cluster / FaaS-backend substrate (the OpenFaaS + faasd stand-in).
//!
//! The paper organizes every resource — a faasd Raspberry Pi, an edge
//! Kubernetes cluster, the cloud cluster — as "an OpenFaaS resource which
//! exposes a gateway to EdgeFaaS". This module is that resource:
//!
//! * [`spec`] — capability vectors from the registration YAML (Table 1) and
//!   Table 3's testbed presets;
//! * [`sandbox`] — function-sandbox lifecycle: cold start, warm pool,
//!   scale-up/down, per-sandbox memory/GPU accounting;
//! * [`faas`] — the FaaS backend proper: deploy / remove / describe / list /
//!   invoke over an [`Executor`](faas::Executor) that either runs real
//!   compute (PJRT) or a modeled latency (virtual-time benches);
//! * [`gateway`] — the per-resource REST gateway speaking OpenFaaS-shaped
//!   verbs (`/system/functions`, `/function/{name}`), with the `pwd`
//!   credential check from the registration file.

pub mod faas;
pub mod gateway;
pub mod sandbox;
pub mod spec;

pub use faas::{BatchCall, Executor, FaasBackend, FunctionSpec, NativeExecutor};
pub use spec::ResourceSpec;
