//! Per-resource REST gateway (the OpenFaaS gateway stand-in).
//!
//! "Each OpenFaaS resource exposes a gateway (including Faasd) to EdgeFaaS
//! through which EdgeFaaS deploys functions on the resource" (§3.1).
//! Endpoints mirror the OpenFaaS shapes EdgeFaaS needs:
//!
//! ```text
//! POST   /system/functions          deploy   {name, image, memory, gpus, labels}
//! DELETE /system/functions          remove   {name}
//! GET    /system/functions          list
//! GET    /system/function/{name}    describe
//! POST   /function/{name}           invoke (sync; body = payload)
//! GET    /healthz
//! ```
//!
//! Administrative verbs require the resource `pwd` in the `Authorization`
//! header, mirroring the paper's "pwd is the password to authenticate the
//! administrative API Gateway".

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::http::{Handler, Request, Response, Server};
use crate::util::json::Json;

use super::faas::{FaasBackend, FunctionSpec};

/// HTTP facade over a [`FaasBackend`].
pub struct FaasGateway {
    backend: Arc<FaasBackend>,
}

impl FaasGateway {
    pub fn new(backend: Arc<FaasBackend>) -> Self {
        FaasGateway { backend }
    }

    /// Serve on an ephemeral local port; returns the server handle.
    pub fn serve(backend: Arc<FaasBackend>, workers: usize) -> anyhow::Result<Server> {
        let gw = Arc::new(FaasGateway::new(backend));
        Server::bind(0, workers, gw as Arc<dyn Handler>)
    }

    fn authorized(&self, req: &Request) -> bool {
        req.headers.get("authorization").map(|v| v.as_str())
            == Some(self.backend.spec.pwd.as_str())
    }

    fn deploy(&self, req: &Request) -> Response {
        if !self.authorized(req) {
            return Response::text(401, "bad credentials");
        }
        let body = match req.json() {
            Ok(v) => v,
            Err(e) => return Response::bad_request(format!("bad json: {e}")),
        };
        let spec = match parse_function_spec(&body) {
            Ok(s) => s,
            Err(e) => return Response::bad_request(e.to_string()),
        };
        match self.backend.deploy(spec) {
            Ok(()) => Response::text(201, "deployed"),
            Err(e) => Response::text(409, e.to_string()),
        }
    }

    fn remove(&self, req: &Request) -> Response {
        if !self.authorized(req) {
            return Response::text(401, "bad credentials");
        }
        let name = match req.json().and_then(|v| Ok(v.req_str("name")?.to_string())) {
            Ok(n) => n,
            Err(e) => return Response::bad_request(e.to_string()),
        };
        match self.backend.remove(&name) {
            Ok(()) => Response::text(200, "removed"),
            Err(e) => Response::text(404, e.to_string()),
        }
    }

    fn describe(&self, name: &str) -> Response {
        match self.backend.describe(name) {
            Ok(st) => {
                let mut o = Json::obj();
                o.set("name", st.spec.name.as_str().into())
                    .set("image", st.spec.image.as_str().into())
                    .set("memory", st.spec.memory.into())
                    .set("gpus", (st.spec.gpus as u64).into())
                    .set("replicas", (st.replicas as u64).into())
                    .set("invocations", st.invocations.into())
                    .set("url", st.url.as_str().into());
                let mut labels = Json::obj();
                for (k, v) in &st.spec.labels {
                    labels.set(k, v.as_str().into());
                }
                o.set("labels", labels);
                Response::json(200, &o)
            }
            Err(e) => Response::text(404, e.to_string()),
        }
    }

    fn invoke(&self, name: &str, req: &Request) -> Response {
        match self.backend.invoke(name, &req.body) {
            Ok((out, latency)) => {
                let mut r = Response::bytes(200, out);
                r.headers.insert("X-Duration-Seconds".into(), format!("{latency:.6}"));
                r
            }
            Err(e) => Response::error(e.to_string()),
        }
    }
}

impl Handler for FaasGateway {
    fn handle(&self, req: Request) -> Response {
        let segs = req.segments();
        match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["healthz"]) => Response::text(200, "ok"),
            ("POST", ["system", "functions"]) => self.deploy(&req),
            ("DELETE", ["system", "functions"]) => self.remove(&req),
            ("GET", ["system", "functions"]) => {
                let names = self.backend.list();
                Response::json(200, &Json::from(names))
            }
            ("GET", ["system", "function", name]) => self.describe(name),
            ("POST", ["function", name]) => self.invoke(name, &req),
            _ => Response::not_found(),
        }
    }
}

fn parse_function_spec(v: &Json) -> anyhow::Result<FunctionSpec> {
    let mut labels = HashMap::new();
    if let Some(obj) = v.get("labels").and_then(Json::as_obj) {
        for (k, lv) in obj {
            if let Some(s) = lv.as_str() {
                labels.insert(k.clone(), s.to_string());
            }
        }
    }
    Ok(FunctionSpec {
        name: v.req_str("name")?.to_string(),
        image: v.req_str("image")?.to_string(),
        memory: v.get("memory").and_then(Json::as_u64).unwrap_or(128 << 20),
        gpus: v.get("gpus").and_then(Json::as_u64).unwrap_or(0) as u32,
        labels,
    })
}

/// Client helpers for talking to a FaasGateway (used by the coordinator).
pub mod client {
    use crate::util::http;
    use crate::util::json::Json;

    /// Deploy a function through a resource gateway.
    pub fn deploy(
        addr: &str,
        pwd: &str,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        let mut body = Json::obj();
        body.set("name", name.into())
            .set("image", image.into())
            .set("memory", memory.into())
            .set("gpus", (gpus as u64).into());
        let mut l = Json::obj();
        for (k, v) in labels {
            l.set(k, v.as_str().into());
        }
        body.set("labels", l);
        let resp = http::request(
            addr,
            "POST",
            "/system/functions",
            &[("Authorization", pwd), ("Content-Type", "application/json")],
            body.to_string().as_bytes(),
        )?;
        if !resp.ok() {
            anyhow::bail!("deploy {name} on {addr}: {} {}", resp.status, resp.body_str().unwrap_or(""));
        }
        Ok(())
    }

    /// Remove a function through a resource gateway.
    pub fn remove(addr: &str, pwd: &str, name: &str) -> anyhow::Result<()> {
        let mut body = Json::obj();
        body.set("name", name.into());
        let resp = http::request(
            addr,
            "DELETE",
            "/system/functions",
            &[("Authorization", pwd), ("Content-Type", "application/json")],
            body.to_string().as_bytes(),
        )?;
        if !resp.ok() {
            anyhow::bail!("remove {name} on {addr}: {}", resp.status);
        }
        Ok(())
    }

    /// Describe a function; returns the raw JSON document.
    pub fn describe(addr: &str, name: &str) -> anyhow::Result<Json> {
        let resp = http::get(addr, &format!("/system/function/{name}"))?;
        if !resp.ok() {
            anyhow::bail!("describe {name} on {addr}: {}", resp.status);
        }
        resp.json_body()
    }

    /// Invoke a function synchronously; returns (output, reported latency).
    pub fn invoke(addr: &str, name: &str, payload: &[u8]) -> anyhow::Result<(Vec<u8>, f64)> {
        let resp = http::post_bytes(addr, &format!("/function/{name}"), payload)?;
        if !resp.ok() {
            anyhow::bail!(
                "invoke {name} on {addr}: {} {}",
                resp.status,
                resp.body_str().unwrap_or("")
            );
        }
        let latency = resp
            .headers
            .get("x-duration-seconds")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.0);
        Ok((resp.body, latency))
    }

    /// List deployed functions.
    pub fn list(addr: &str) -> anyhow::Result<Vec<String>> {
        let resp = http::get(addr, "/system/functions")?;
        if !resp.ok() {
            anyhow::bail!("list on {addr}: {}", resp.status);
        }
        let v = resp.json_body()?;
        Ok(v.as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_str().map(String::from))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::faas::NativeExecutor;
    use crate::cluster::spec::ResourceSpec;
    use crate::simnet::RealClock;

    fn gateway() -> (Server, Arc<FaasBackend>) {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        let spec = ResourceSpec::paper_edge("unused");
        let backend = Arc::new(FaasBackend::new(
            spec,
            exec as Arc<dyn super::super::faas::Executor>,
            Arc::new(RealClock::new()),
        ));
        let server = FaasGateway::serve(Arc::clone(&backend), 4).unwrap();
        (server, backend)
    }

    #[test]
    fn full_rest_lifecycle() {
        let (server, _) = gateway();
        let addr = server.addr();
        let pwd = "edgepwd";
        client::deploy(&addr, pwd, "echo", "img/echo", 128 << 20, 0, &[]).unwrap();
        assert_eq!(client::list(&addr).unwrap(), vec!["echo".to_string()]);
        let (out, lat) = client::invoke(&addr, "echo", b"ping").unwrap();
        assert_eq!(out, b"ping");
        assert!(lat >= 0.0);
        let desc = client::describe(&addr, "echo").unwrap();
        assert_eq!(desc.get("invocations").unwrap().as_u64(), Some(1));
        client::remove(&addr, pwd, "echo").unwrap();
        assert!(client::invoke(&addr, "echo", b"x").is_err());
    }

    #[test]
    fn auth_required_for_admin_verbs() {
        let (server, _) = gateway();
        let addr = server.addr();
        assert!(client::deploy(&addr, "wrongpwd", "f", "img/echo", 1 << 20, 0, &[]).is_err());
        // Invoke needs no admin auth (matches OpenFaaS function path).
        client::deploy(&addr, "edgepwd", "f", "img/echo", 1 << 20, 0, &[]).unwrap();
        assert!(client::invoke(&addr, "f", b"x").is_ok());
    }

    #[test]
    fn healthz() {
        let (server, _) = gateway();
        let resp = crate::util::http::get(&server.addr(), "/healthz").unwrap();
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn labels_roundtrip() {
        let (server, _) = gateway();
        let addr = server.addr();
        client::deploy(
            &addr,
            "edgepwd",
            "f",
            "img/echo",
            1 << 20,
            0,
            &[("app".to_string(), "videopipeline".to_string())],
        )
        .unwrap();
        let desc = client::describe(&addr, "f").unwrap();
        assert_eq!(desc.get("labels").unwrap().get("app").unwrap().as_str(), Some("videopipeline"));
    }
}
