//! Per-resource REST gateway (the OpenFaaS gateway stand-in).
//!
//! "Each OpenFaaS resource exposes a gateway (including Faasd) to EdgeFaaS
//! through which EdgeFaaS deploys functions on the resource" (§3.1).
//! Endpoints mirror the OpenFaaS shapes EdgeFaaS needs:
//!
//! ```text
//! POST   /system/functions          deploy   {name, image, memory, gpus, labels}
//! DELETE /system/functions          remove   {name}
//! GET    /system/functions          list
//! GET    /system/function/{name}    describe
//! POST   /function/{name}           invoke (sync; body = payload)
//! POST   /function/_batch           invoke many in one round trip:
//!                                   {calls:[{name, payload}, ...]} ->
//!                                   {results:[{ok, output, latency}|{ok, error}]}
//! GET    /healthz
//! ```
//!
//! The `_batch` verb is the wire half of the engine's per-resource
//! invocation batching: one HTTP round trip carries a whole batch, with
//! per-entry results (a failing or panicking entry does not fail its
//! siblings). Payloads/outputs on this path are JSON-embedded text — which
//! the engine's envelopes and `{"outputs": [...]}` responses always are;
//! binary payloads fall back to per-call `POST /function/{name}`. A
//! function literally named `_batch` is shadowed by this verb.
//!
//! Administrative verbs require the resource `pwd` in the `Authorization`
//! header, mirroring the paper's "pwd is the password to authenticate the
//! administrative API Gateway".

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::bytes::Bytes;
use crate::util::http::{Handler, Request, Response, Server};
use crate::util::json::Json;

use super::faas::{FaasBackend, FunctionSpec};

/// HTTP facade over a [`FaasBackend`].
pub struct FaasGateway {
    backend: Arc<FaasBackend>,
}

impl FaasGateway {
    pub fn new(backend: Arc<FaasBackend>) -> Self {
        FaasGateway { backend }
    }

    /// Serve on an ephemeral local port; returns the server handle.
    pub fn serve(backend: Arc<FaasBackend>, workers: usize) -> anyhow::Result<Server> {
        let gw = Arc::new(FaasGateway::new(backend));
        Server::bind(0, workers, gw as Arc<dyn Handler>)
    }

    fn authorized(&self, req: &Request) -> bool {
        req.headers.get("authorization").map(|v| v.as_str())
            == Some(self.backend.spec.pwd.as_str())
    }

    fn deploy(&self, req: &Request) -> Response {
        if !self.authorized(req) {
            return Response::text(401, "bad credentials");
        }
        let body = match req.json() {
            Ok(v) => v,
            Err(e) => return Response::bad_request(format!("bad json: {e}")),
        };
        let spec = match parse_function_spec(&body) {
            Ok(s) => s,
            Err(e) => return Response::bad_request(e.to_string()),
        };
        match self.backend.deploy(spec) {
            Ok(()) => Response::text(201, "deployed"),
            Err(e) => Response::text(409, e.to_string()),
        }
    }

    fn remove(&self, req: &Request) -> Response {
        if !self.authorized(req) {
            return Response::text(401, "bad credentials");
        }
        let name = match req.json().and_then(|v| Ok(v.req_str("name")?.to_string())) {
            Ok(n) => n,
            Err(e) => return Response::bad_request(e.to_string()),
        };
        match self.backend.remove(&name) {
            Ok(()) => Response::text(200, "removed"),
            Err(e) => Response::text(404, e.to_string()),
        }
    }

    fn describe(&self, name: &str) -> Response {
        match self.backend.describe(name) {
            Ok(st) => {
                let mut o = Json::obj();
                o.set("name", st.spec.name.as_str().into())
                    .set("image", (&*st.spec.image).into())
                    .set("memory", st.spec.memory.into())
                    .set("gpus", (st.spec.gpus as u64).into())
                    .set("replicas", (st.replicas as u64).into())
                    .set("invocations", st.invocations.into())
                    .set("url", st.url.as_str().into());
                let mut labels = Json::obj();
                for (k, v) in &st.spec.labels {
                    labels.set(k, v.as_str().into());
                }
                o.set("labels", labels);
                Response::json(200, &o)
            }
            Err(e) => Response::text(404, e.to_string()),
        }
    }

    fn invoke(&self, name: &str, req: &Request) -> Response {
        // Process boundary: copy the request body into a shared buffer once.
        match self.backend.invoke(name, &Bytes::copy_from(&req.body)) {
            Ok((out, latency)) => {
                let mut r = Response::bytes(200, out.to_vec());
                r.headers.insert("X-Duration-Seconds".into(), format!("{latency:.6}"));
                r
            }
            Err(e) => Response::error(e.to_string()),
        }
    }

    /// The batch verb: parse `{calls: [{name, payload}, ...]}`, execute the
    /// whole batch through [`FaasBackend::invoke_batch`] (per-entry failure
    /// containment), and answer with one result per entry.
    fn invoke_batch(&self, req: &Request) -> Response {
        let body = match req.json() {
            Ok(v) => v,
            Err(e) => return Response::bad_request(format!("bad json: {e}")),
        };
        let Some(entries) = body.get("calls").and_then(Json::as_arr) else {
            return Response::bad_request("missing `calls` array".to_string());
        };
        let mut calls: Vec<(String, Bytes)> = Vec::with_capacity(entries.len());
        for entry in entries {
            let parsed = entry
                .req_str("name")
                .map(String::from)
                .and_then(|n| Ok((n, Bytes::from(entry.req_str("payload")?))));
            match parsed {
                Ok(call) => calls.push(call),
                Err(e) => return Response::bad_request(format!("bad batch entry: {e}")),
            }
        }
        let results = self.backend.invoke_batch(&calls);
        let mut arr = Vec::with_capacity(results.len());
        for result in results {
            let mut o = Json::obj();
            match result {
                Ok((out, latency)) => {
                    o.set("ok", true.into()).set("latency", latency.into());
                    // Text outputs (the engine's `{"outputs": [...]}`
                    // responses) travel as-is; binary outputs are
                    // hex-encoded so the batch path is lossless — never
                    // lossily transcoded.
                    match std::str::from_utf8(&out) {
                        Ok(text) => o.set("output", text.into()),
                        Err(_) => o.set("output_hex", hex_encode(&out).as_str().into()),
                    };
                }
                Err(e) => {
                    o.set("ok", false.into()).set("error", e.to_string().as_str().into());
                }
            }
            arr.push(o);
        }
        let mut resp = Json::obj();
        resp.set("results", Json::Arr(arr));
        Response::json(200, &resp)
    }
}

impl Handler for FaasGateway {
    fn handle(&self, req: Request) -> Response {
        let segs = req.segments();
        match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["healthz"]) => Response::text(200, "ok"),
            ("POST", ["system", "functions"]) => self.deploy(&req),
            ("DELETE", ["system", "functions"]) => self.remove(&req),
            ("GET", ["system", "functions"]) => {
                let names = self.backend.list();
                Response::json(200, &Json::from(names))
            }
            ("GET", ["system", "function", name]) => self.describe(name),
            // `_batch` must match before the single-invoke wildcard.
            ("POST", ["function", "_batch"]) => self.invoke_batch(&req),
            ("POST", ["function", name]) => self.invoke(name, &req),
            _ => Response::not_found(),
        }
    }
}

/// Lowercase hex for binary outputs on the `_batch` wire (JSON strings
/// cannot carry arbitrary bytes).
fn hex_encode(data: &[u8]) -> String {
    let mut s = String::with_capacity(data.len() * 2);
    for b in data {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

fn hex_decode(s: &str) -> anyhow::Result<Vec<u8>> {
    // ASCII guard first: byte-offset slicing below would panic on a
    // multi-byte UTF-8 char boundary from a misbehaving peer.
    anyhow::ensure!(s.is_ascii(), "non-ASCII hex string");
    anyhow::ensure!(s.len() % 2 == 0, "odd-length hex string");
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|_| anyhow::anyhow!("bad hex byte `{}`", &s[i..i + 2]))
        })
        .collect()
}

fn parse_function_spec(v: &Json) -> anyhow::Result<FunctionSpec> {
    let mut labels = HashMap::new();
    if let Some(obj) = v.get("labels").and_then(Json::as_obj) {
        for (k, lv) in obj {
            if let Some(s) = lv.as_str() {
                labels.insert(k.clone(), s.to_string());
            }
        }
    }
    Ok(FunctionSpec {
        name: v.req_str("name")?.to_string(),
        image: v.req_str("image")?.into(),
        memory: v.get("memory").and_then(Json::as_u64).unwrap_or(128 << 20),
        gpus: v.get("gpus").and_then(Json::as_u64).unwrap_or(0) as u32,
        labels,
    })
}

/// Client helpers for talking to a FaasGateway (used by the coordinator).
pub mod client {
    use crate::util::http;
    use crate::util::json::Json;

    /// Deploy a function through a resource gateway.
    pub fn deploy(
        addr: &str,
        pwd: &str,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        let mut body = Json::obj();
        body.set("name", name.into())
            .set("image", image.into())
            .set("memory", memory.into())
            .set("gpus", (gpus as u64).into());
        let mut l = Json::obj();
        for (k, v) in labels {
            l.set(k, v.as_str().into());
        }
        body.set("labels", l);
        let resp = http::request(
            addr,
            "POST",
            "/system/functions",
            &[("Authorization", pwd), ("Content-Type", "application/json")],
            body.to_string().as_bytes(),
        )?;
        if !resp.ok() {
            anyhow::bail!("deploy {name} on {addr}: {} {}", resp.status, resp.body_str().unwrap_or(""));
        }
        Ok(())
    }

    /// Remove a function through a resource gateway.
    pub fn remove(addr: &str, pwd: &str, name: &str) -> anyhow::Result<()> {
        let mut body = Json::obj();
        body.set("name", name.into());
        let resp = http::request(
            addr,
            "DELETE",
            "/system/functions",
            &[("Authorization", pwd), ("Content-Type", "application/json")],
            body.to_string().as_bytes(),
        )?;
        if !resp.ok() {
            anyhow::bail!("remove {name} on {addr}: {}", resp.status);
        }
        Ok(())
    }

    /// Describe a function; returns the raw JSON document.
    pub fn describe(addr: &str, name: &str) -> anyhow::Result<Json> {
        let resp = http::get(addr, &format!("/system/function/{name}"))?;
        if !resp.ok() {
            anyhow::bail!("describe {name} on {addr}: {}", resp.status);
        }
        resp.json_body()
    }

    /// Invoke a function synchronously; returns (output, reported latency).
    pub fn invoke(addr: &str, name: &str, payload: &[u8]) -> anyhow::Result<(Vec<u8>, f64)> {
        let resp = http::post_bytes(addr, &format!("/function/{name}"), payload)?;
        if !resp.ok() {
            anyhow::bail!(
                "invoke {name} on {addr}: {} {}",
                resp.status,
                resp.body_str().unwrap_or("")
            );
        }
        let latency = resp
            .headers
            .get("x-duration-seconds")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.0);
        Ok((resp.body, latency))
    }

    /// Invoke a batch of functions in one round trip via `_batch`.
    ///
    /// `Ok(Some(results))` carries one result per call. `Ok(None)` means
    /// the gateway *refused before executing anything* (404/400 — e.g. an
    /// older gateway without the verb), so the caller may safely fall back
    /// to per-call invokes. Any other failure (transport error, non-OK
    /// status, malformed or short response) returns `Err`: the gateway may
    /// already have executed the batch, so retrying would double-execute.
    /// Fails whole when a payload is not UTF-8 (the JSON wire format
    /// carries payloads as text — the engine's envelopes always are).
    #[allow(clippy::type_complexity)]
    pub fn invoke_batch(
        addr: &str,
        calls: &[(String, crate::util::bytes::Bytes)],
    ) -> anyhow::Result<Option<Vec<anyhow::Result<(crate::util::bytes::Bytes, f64)>>>> {
        let mut entries = Vec::with_capacity(calls.len());
        for (name, payload) in calls {
            let text = std::str::from_utf8(payload)
                .map_err(|_| anyhow::anyhow!("batch wire path requires UTF-8 payloads"))?;
            let mut o = Json::obj();
            o.set("name", name.as_str().into()).set("payload", text.into());
            entries.push(o);
        }
        let mut body = Json::obj();
        body.set("calls", Json::Arr(entries));
        let resp = http::request(
            addr,
            "POST",
            "/function/_batch",
            &[("Content-Type", "application/json")],
            body.to_string().as_bytes(),
        )?;
        if resp.status == 404 || resp.status == 400 {
            // Refused before execution: the verb is unknown to this
            // gateway (or the request was rejected at parse time).
            return Ok(None);
        }
        if !resp.ok() {
            anyhow::bail!(
                "batch invoke on {addr}: {} {}",
                resp.status,
                resp.body_str().unwrap_or("")
            );
        }
        let v = resp.json_body()?;
        let results = v
            .get("results")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("malformed batch response"))?;
        anyhow::ensure!(
            results.len() == calls.len(),
            "batch response arity {} != {} calls",
            results.len(),
            calls.len()
        );
        let decoded = results
            .iter()
            .map(|r| {
                if r.get("ok").and_then(Json::as_bool).unwrap_or(false) {
                    let lat = r.get("latency").and_then(Json::as_f64).unwrap_or(0.0);
                    let out = match r.get("output_hex").and_then(Json::as_str) {
                        Some(hexed) => crate::util::bytes::Bytes::from(super::hex_decode(hexed)?),
                        None => crate::util::bytes::Bytes::from(
                            r.get("output").and_then(Json::as_str).unwrap_or(""),
                        ),
                    };
                    Ok((out, lat))
                } else {
                    let msg =
                        r.get("error").and_then(Json::as_str).unwrap_or("batch entry failed");
                    Err(anyhow::anyhow!(msg.to_string()))
                }
            })
            .collect();
        Ok(Some(decoded))
    }

    /// List deployed functions.
    pub fn list(addr: &str) -> anyhow::Result<Vec<String>> {
        let resp = http::get(addr, "/system/functions")?;
        if !resp.ok() {
            anyhow::bail!("list on {addr}: {}", resp.status);
        }
        let v = resp.json_body()?;
        Ok(v.as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_str().map(String::from))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::faas::NativeExecutor;
    use crate::cluster::spec::ResourceSpec;
    use crate::simnet::RealClock;

    fn gateway() -> (Server, Arc<FaasBackend>) {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        let spec = ResourceSpec::paper_edge("unused");
        let backend = Arc::new(FaasBackend::new(
            spec,
            exec as Arc<dyn super::super::faas::Executor>,
            Arc::new(RealClock::new()),
        ));
        let server = FaasGateway::serve(Arc::clone(&backend), 4).unwrap();
        (server, backend)
    }

    #[test]
    fn full_rest_lifecycle() {
        let (server, _) = gateway();
        let addr = server.addr();
        let pwd = "edgepwd";
        client::deploy(&addr, pwd, "echo", "img/echo", 128 << 20, 0, &[]).unwrap();
        assert_eq!(client::list(&addr).unwrap(), vec!["echo".to_string()]);
        let (out, lat) = client::invoke(&addr, "echo", b"ping").unwrap();
        assert_eq!(out, b"ping");
        assert!(lat >= 0.0);
        let desc = client::describe(&addr, "echo").unwrap();
        assert_eq!(desc.get("invocations").unwrap().as_u64(), Some(1));
        client::remove(&addr, pwd, "echo").unwrap();
        assert!(client::invoke(&addr, "echo", b"x").is_err());
    }

    #[test]
    fn auth_required_for_admin_verbs() {
        let (server, _) = gateway();
        let addr = server.addr();
        assert!(client::deploy(&addr, "wrongpwd", "f", "img/echo", 1 << 20, 0, &[]).is_err());
        // Invoke needs no admin auth (matches OpenFaaS function path).
        client::deploy(&addr, "edgepwd", "f", "img/echo", 1 << 20, 0, &[]).unwrap();
        assert!(client::invoke(&addr, "f", b"x").is_ok());
    }

    #[test]
    fn batch_endpoint_invokes_many_in_one_round_trip() {
        let (server, backend) = gateway();
        let addr = server.addr();
        client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();
        let calls = vec![
            ("echo".to_string(), Bytes::from("a")),
            ("ghost".to_string(), Bytes::from("x")),
            ("echo".to_string(), Bytes::from("b")),
        ];
        let results = client::invoke_batch(&addr, &calls).unwrap().expect("verb supported");
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].as_ref().unwrap().0, &b"a"[..]);
        assert!(results[1].is_err(), "unknown function fails its entry only");
        assert_eq!(results[2].as_ref().unwrap().0, &b"b"[..]);
        assert_eq!(backend.describe("echo").unwrap().invocations, 2);
    }

    #[test]
    fn batch_endpoint_roundtrips_binary_outputs_losslessly() {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/bin", |_: &[u8]| Ok(vec![0xff, 0x00, 0xfe, b'x']));
        let spec = ResourceSpec::paper_edge("unused");
        let backend = Arc::new(FaasBackend::new(
            spec,
            exec as Arc<dyn super::super::faas::Executor>,
            Arc::new(RealClock::new()),
        ));
        let server = FaasGateway::serve(Arc::clone(&backend), 2).unwrap();
        let addr = server.addr();
        client::deploy(&addr, "edgepwd", "bin", "img/bin", 1 << 20, 0, &[]).unwrap();
        let calls = vec![("bin".to_string(), Bytes::from("{}"))];
        let results = client::invoke_batch(&addr, &calls).unwrap().expect("verb supported");
        assert_eq!(
            results[0].as_ref().unwrap().0,
            &[0xff, 0x00, 0xfe, b'x'][..],
            "binary output survives the hex leg of the batch wire format"
        );
        assert_eq!(hex_decode(&hex_encode(&[0xde, 0xad, 0x01])).unwrap(), vec![0xde, 0xad, 0x01]);
        assert!(hex_decode("zz").is_err(), "non-hex characters rejected");
        assert!(hex_decode("abc").is_err(), "odd length rejected");
    }

    #[test]
    fn healthz() {
        let (server, _) = gateway();
        let resp = crate::util::http::get(&server.addr(), "/healthz").unwrap();
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn labels_roundtrip() {
        let (server, _) = gateway();
        let addr = server.addr();
        client::deploy(
            &addr,
            "edgepwd",
            "f",
            "img/echo",
            1 << 20,
            0,
            &[("app".to_string(), "videopipeline".to_string())],
        )
        .unwrap();
        let desc = client::describe(&addr, "f").unwrap();
        assert_eq!(desc.get("labels").unwrap().get("app").unwrap().as_str(), Some("videopipeline"));
    }
}
