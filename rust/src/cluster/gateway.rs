//! Per-resource REST gateway (the OpenFaaS gateway stand-in).
//!
//! "Each OpenFaaS resource exposes a gateway (including Faasd) to EdgeFaaS
//! through which EdgeFaaS deploys functions on the resource" (§3.1).
//! Endpoints mirror the OpenFaaS shapes EdgeFaaS needs:
//!
//! ```text
//! POST   /system/functions          deploy   {name, image, memory, gpus, labels}
//! DELETE /system/functions          remove   {name}
//! GET    /system/functions          list
//! GET    /system/function/{name}    describe
//! POST   /function/{name}           invoke (sync; body = payload)
//! POST   /function/_batch           invoke many in one round trip:
//!                                   binary frames (preferred) or JSON
//! GET    /healthz
//! ```
//!
//! The `_batch` verb is the wire half of the engine's per-resource
//! invocation batching: one HTTP round trip carries a whole batch, with
//! per-entry results (a failing or panicking entry does not fail its
//! siblings). A function literally named `_batch` is shadowed by this
//! verb. Two wire formats, negotiated by `Content-Type`:
//!
//! * **Binary frames** ([`BATCH_BINARY_CONTENT_TYPE`]) — the streaming
//!   format: an `EFB2` magic, a little-endian `u32` call count, then one
//!   `(attempt u64, name, payload)` length-prefixed frame per call; the
//!   response mirrors it with one `(ok, latency, output | error)` frame
//!   per entry under the original `EFB1` magic. The request decoder also
//!   accepts v1 (`EFB1`, no attempt field — attempt 0) from older
//!   clients; an older *gateway* rejects `EFB2` at parse time (400), which
//!   the client treats as a pre-execution refusal and downgrades to JSON.
//!   Payloads and outputs are raw bytes, so binary data travels at 1x
//!   (the JSON format hex-encodes it at 2x) and needs no UTF-8 guard. The
//!   attempt id is the liveness plane's at-most-once retry key (see
//!   [`BatchCall`]).
//! * **JSON** (anything else) — `{calls:[{name, payload, attempt?}, ...]}`
//!   -> `{results:[{ok, output|output_hex, latency}|{ok, error}]}`, kept
//!   for old peers; text payloads ride as-is, binary outputs are
//!   hex-encoded so the path stays lossless; a missing `attempt` means 0
//!   (no dedup). The coordinator's client tries the binary format first
//!   and falls back to JSON — and then to per-call
//!   `POST /function/{name}` — only on a pre-execution refusal.
//!
//! Administrative verbs require the resource `pwd` in the `Authorization`
//! header, mirroring the paper's "pwd is the password to authenticate the
//! administrative API Gateway".

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::bytes::Bytes;
use crate::util::http::{Handler, Request, Response, Server};
use crate::util::json::Json;

use super::faas::{BatchCall, FaasBackend, FunctionSpec};

/// HTTP facade over a [`FaasBackend`].
pub struct FaasGateway {
    backend: Arc<FaasBackend>,
}

impl FaasGateway {
    pub fn new(backend: Arc<FaasBackend>) -> Self {
        FaasGateway { backend }
    }

    /// Serve on an ephemeral local port; returns the server handle.
    pub fn serve(backend: Arc<FaasBackend>, workers: usize) -> anyhow::Result<Server> {
        let gw = Arc::new(FaasGateway::new(backend));
        Server::bind(0, workers, gw as Arc<dyn Handler>)
    }

    fn authorized(&self, req: &Request) -> bool {
        req.headers.get("authorization").map(|v| v.as_str())
            == Some(self.backend.spec.pwd.as_str())
    }

    fn deploy(&self, req: &Request) -> Response {
        if !self.authorized(req) {
            return Response::text(401, "bad credentials");
        }
        let body = match req.json() {
            Ok(v) => v,
            Err(e) => return Response::bad_request(format!("bad json: {e}")),
        };
        let spec = match parse_function_spec(&body) {
            Ok(s) => s,
            Err(e) => return Response::bad_request(e.to_string()),
        };
        match self.backend.deploy(spec) {
            Ok(()) => Response::text(201, "deployed"),
            Err(e) => Response::text(409, e.to_string()),
        }
    }

    fn remove(&self, req: &Request) -> Response {
        if !self.authorized(req) {
            return Response::text(401, "bad credentials");
        }
        let name = match req.json().and_then(|v| Ok(v.req_str("name")?.to_string())) {
            Ok(n) => n,
            Err(e) => return Response::bad_request(e.to_string()),
        };
        match self.backend.remove(&name) {
            Ok(()) => Response::text(200, "removed"),
            Err(e) => Response::text(404, e.to_string()),
        }
    }

    fn describe(&self, name: &str) -> Response {
        match self.backend.describe(name) {
            Ok(st) => {
                let mut o = Json::obj();
                o.set("name", st.spec.name.as_str().into())
                    .set("image", (&*st.spec.image).into())
                    .set("memory", st.spec.memory.into())
                    .set("gpus", (st.spec.gpus as u64).into())
                    .set("replicas", (st.replicas as u64).into())
                    .set("invocations", st.invocations.into())
                    .set("url", st.url.as_str().into());
                let mut labels = Json::obj();
                for (k, v) in &st.spec.labels {
                    labels.set(k, v.as_str().into());
                }
                o.set("labels", labels);
                Response::json(200, &o)
            }
            Err(e) => Response::text(404, e.to_string()),
        }
    }

    fn invoke(&self, name: &str, req: &Request) -> Response {
        // The parsed body is already a shared buffer; no copy on the way in
        // or out.
        match self.backend.invoke(name, &req.body) {
            Ok((out, latency)) => {
                let mut r = Response::bytes(200, out);
                r.headers.insert("X-Duration-Seconds".into(), format!("{latency:.6}"));
                r
            }
            Err(e) => Response::error(e.to_string()),
        }
    }

    /// The batch verb: decode the calls (binary frames or JSON, by
    /// `Content-Type`), execute the whole batch through
    /// [`FaasBackend::invoke_batch`] (per-entry failure containment), and
    /// answer with one result per entry in the request's format.
    fn invoke_batch(&self, req: &Request) -> Response {
        if req.headers.get("content-type").map(String::as_str) == Some(BATCH_BINARY_CONTENT_TYPE)
        {
            // Decode errors are pre-execution refusals (400), so a client
            // may safely retry through another format or per-call invokes.
            let calls = match decode_binary_calls(&req.body) {
                Ok(calls) => calls,
                Err(e) => return Response::bad_request(format!("bad binary batch: {e}")),
            };
            let results = self.backend.invoke_batch(&calls);
            let mut resp = Response::bytes(200, encode_binary_results(&results));
            resp.headers.insert("Content-Type".into(), BATCH_BINARY_CONTENT_TYPE.into());
            return resp;
        }
        self.invoke_batch_json(req)
    }

    /// The JSON leg of the batch verb (old peers): parse
    /// `{calls: [{name, payload}, ...]}` and answer JSON results.
    fn invoke_batch_json(&self, req: &Request) -> Response {
        let body = match req.json() {
            Ok(v) => v,
            Err(e) => return Response::bad_request(format!("bad json: {e}")),
        };
        let Some(entries) = body.get("calls").and_then(Json::as_arr) else {
            return Response::bad_request("missing `calls` array".to_string());
        };
        let mut calls: Vec<BatchCall> = Vec::with_capacity(entries.len());
        for entry in entries {
            let parsed = entry.req_str("name").map(String::from).and_then(|n| {
                Ok(BatchCall {
                    name: n,
                    payload: Bytes::from(entry.req_str("payload")?),
                    // Optional: old peers send no attempt (0 = no dedup).
                    attempt: entry.get("attempt").and_then(Json::as_u64).unwrap_or(0),
                    budget: None,
                })
            });
            match parsed {
                Ok(call) => calls.push(call),
                Err(e) => return Response::bad_request(format!("bad batch entry: {e}")),
            }
        }
        let results = self.backend.invoke_batch(&calls);
        let mut arr = Vec::with_capacity(results.len());
        for result in results {
            let mut o = Json::obj();
            match result {
                Ok((out, latency)) => {
                    o.set("ok", true.into()).set("latency", latency.into());
                    // Text outputs (the engine's `{"outputs": [...]}`
                    // responses) travel as-is; binary outputs are
                    // hex-encoded so the batch path is lossless — never
                    // lossily transcoded.
                    match std::str::from_utf8(&out) {
                        Ok(text) => o.set("output", text.into()),
                        Err(_) => o.set("output_hex", hex_encode(&out).as_str().into()),
                    };
                }
                Err(e) => {
                    o.set("ok", false.into()).set("error", e.to_string().as_str().into());
                }
            }
            arr.push(o);
        }
        let mut resp = Json::obj();
        resp.set("results", Json::Arr(arr));
        Response::json(200, &resp)
    }
}

impl Handler for FaasGateway {
    fn handle(&self, req: Request) -> Response {
        let segs = req.segments();
        match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["healthz"]) => Response::text(200, "ok"),
            ("POST", ["system", "functions"]) => self.deploy(&req),
            ("DELETE", ["system", "functions"]) => self.remove(&req),
            ("GET", ["system", "functions"]) => {
                let names = self.backend.list();
                Response::json(200, &Json::from(names))
            }
            ("GET", ["system", "function", name]) => self.describe(name),
            // `_batch` must match before the single-invoke wildcard.
            ("POST", ["function", "_batch"]) => self.invoke_batch(&req),
            ("POST", ["function", name]) => self.invoke(name, &req),
            _ => Response::not_found(),
        }
    }
}

/// Lowercase hex for binary outputs on the `_batch` wire (JSON strings
/// cannot carry arbitrary bytes).
fn hex_encode(data: &[u8]) -> String {
    let mut s = String::with_capacity(data.len() * 2);
    for b in data {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

fn hex_decode(s: &str) -> anyhow::Result<Vec<u8>> {
    // ASCII guard first: byte-offset slicing below would panic on a
    // multi-byte UTF-8 char boundary from a misbehaving peer.
    anyhow::ensure!(s.is_ascii(), "non-ASCII hex string");
    anyhow::ensure!(s.len() % 2 == 0, "odd-length hex string");
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|_| anyhow::anyhow!("bad hex byte `{}`", &s[i..i + 2]))
        })
        .collect()
}

/// `Content-Type` of the length-prefixed binary `_batch` wire format.
pub const BATCH_BINARY_CONTENT_TYPE: &str = "application/x-edgefaas-batch";

/// v1 magic: responses always use it; v1 requests carry `(name, payload)`
/// frames with no attempt ids (decoded as attempt 0).
const BATCH_MAGIC: &[u8; 4] = b"EFB1";

/// v2 request magic: each call frame is `(attempt u64, name, payload)`.
/// Encoders emit v2; a v1-only gateway rejects the magic at parse time
/// (pre-execution 400), so the client's refusal downgrade applies.
const BATCH_MAGIC2: &[u8; 4] = b"EFB2";

/// Bounds-checked little-endian reader over a binary batch body.
struct FrameReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    fn new(buf: &'a [u8]) -> anyhow::Result<FrameReader<'a>> {
        anyhow::ensure!(buf.len() >= 8 && &buf[..4] == BATCH_MAGIC, "bad batch magic");
        Ok(FrameReader { buf, pos: 4 })
    }

    /// Accept a request body under either magic. Returns `(reader, v2)`:
    /// `v2 = true` means each call frame leads with a `u64` attempt id.
    fn new_request(buf: &'a [u8]) -> anyhow::Result<(FrameReader<'a>, bool)> {
        anyhow::ensure!(buf.len() >= 8, "short batch frame");
        let v2 = match &buf[..4] {
            m if m == BATCH_MAGIC => false,
            m if m == BATCH_MAGIC2 => true,
            _ => anyhow::bail!("bad batch magic"),
        };
        Ok((FrameReader { buf, pos: 4 }, v2))
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.buf.len() - self.pos >= n, "truncated batch frame");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }

    /// A `u32` length followed by that many bytes. The length is checked
    /// against the remaining buffer before any allocation, so a
    /// misbehaving peer cannot make us reserve gigabytes.
    fn blob(&mut self) -> anyhow::Result<&'a [u8]> {
        let len = self.u32()? as usize;
        self.take(len)
    }

    /// Like [`FrameReader::blob`], but returns the blob's byte range so a
    /// caller holding the backing [`Bytes`] can slice a zero-copy window
    /// instead of copying the payload out.
    fn blob_range(&mut self) -> anyhow::Result<(usize, usize)> {
        let len = self.u32()? as usize;
        let start = self.pos;
        self.take(len)?;
        Ok((start, start + len))
    }

    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.pos == self.buf.len(), "trailing bytes after batch frames");
        Ok(())
    }
}

fn push_blob(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

/// Encode `calls` as a v2 (`EFB2`) binary batch request body: one
/// `(attempt u64, name blob, payload blob)` frame per call.
pub(crate) fn encode_binary_calls(calls: &[BatchCall]) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        8 + calls.iter().map(|c| 16 + c.name.len() + c.payload.len()).sum::<usize>(),
    );
    out.extend_from_slice(BATCH_MAGIC2);
    out.extend_from_slice(&(calls.len() as u32).to_le_bytes());
    for call in calls {
        out.extend_from_slice(&call.attempt.to_le_bytes());
        push_blob(&mut out, call.name.as_bytes());
        push_blob(&mut out, &call.payload);
    }
    out
}

/// Decode a binary batch request body (v1 or v2) into [`BatchCall`]s. Each
/// payload is a window into `body`'s allocation — frames stream straight
/// from the request buffer without a copy. v1 frames carry no attempt ids:
/// they decode as attempt 0, i.e. no dedup, preserving the old semantics.
fn decode_binary_calls(body: &Bytes) -> anyhow::Result<Vec<BatchCall>> {
    let (mut r, v2) = FrameReader::new_request(body)?;
    let count = r.u32()? as usize;
    let mut calls = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let attempt = if v2 { r.u64()? } else { 0 };
        let name = std::str::from_utf8(r.blob()?)?.to_string();
        let (start, end) = r.blob_range()?;
        calls.push(BatchCall { name, payload: body.slice(start, end), attempt, budget: None });
    }
    r.done()?;
    Ok(calls)
}

/// Encode per-entry results as a binary batch response body: one
/// `ok(u8) + (latency f64 + output blob | error blob)` frame per entry.
fn encode_binary_results(results: &[anyhow::Result<(Bytes, f64)>]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + results.len() * 16);
    out.extend_from_slice(BATCH_MAGIC);
    out.extend_from_slice(&(results.len() as u32).to_le_bytes());
    for result in results {
        match result {
            Ok((bytes, latency)) => {
                out.push(1);
                out.extend_from_slice(&latency.to_le_bytes());
                push_blob(&mut out, bytes);
            }
            Err(e) => {
                out.push(0);
                push_blob(&mut out, e.to_string().as_bytes());
            }
        }
    }
    out
}

/// Decode a binary batch response body into per-entry results; outputs are
/// zero-copy windows into `body`.
pub(crate) fn decode_binary_results(
    body: &Bytes,
    expected: usize,
) -> anyhow::Result<Vec<anyhow::Result<(Bytes, f64)>>> {
    let mut r = FrameReader::new(body)?;
    let count = r.u32()? as usize;
    anyhow::ensure!(count == expected, "batch response arity {count} != {expected} calls");
    let mut results = Vec::with_capacity(count);
    for _ in 0..count {
        match r.u8()? {
            1 => {
                let latency = r.f64()?;
                let (start, end) = r.blob_range()?;
                results.push(Ok((body.slice(start, end), latency)));
            }
            0 => {
                let msg = String::from_utf8_lossy(r.blob()?).to_string();
                results.push(Err(anyhow::anyhow!(msg)));
            }
            other => anyhow::bail!("bad batch result tag {other}"),
        }
    }
    r.done()?;
    Ok(results)
}

fn parse_function_spec(v: &Json) -> anyhow::Result<FunctionSpec> {
    let mut labels = HashMap::new();
    if let Some(obj) = v.get("labels").and_then(Json::as_obj) {
        for (k, lv) in obj {
            if let Some(s) = lv.as_str() {
                labels.insert(k.clone(), s.to_string());
            }
        }
    }
    Ok(FunctionSpec {
        name: v.req_str("name")?.to_string(),
        image: v.req_str("image")?.into(),
        memory: v.get("memory").and_then(Json::as_u64).unwrap_or(128 << 20),
        gpus: v.get("gpus").and_then(Json::as_u64).unwrap_or(0) as u32,
        labels,
    })
}

/// Client helpers for talking to a FaasGateway (used by the coordinator).
/// Every verb has a `_with` variant taking an explicit
/// [`RequestOptions`](crate::util::http::RequestOptions) budget; the plain
/// form runs under the client defaults.
pub mod client {
    use crate::util::http::{self, RequestOptions};
    use crate::util::json::Json;

    /// Deploy a function through a resource gateway.
    pub fn deploy(
        addr: &str,
        pwd: &str,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        deploy_with(addr, pwd, name, image, memory, gpus, labels, RequestOptions::default())
    }

    /// [`deploy`] under an explicit request budget.
    #[allow(clippy::too_many_arguments)]
    pub fn deploy_with(
        addr: &str,
        pwd: &str,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
        opts: RequestOptions,
    ) -> anyhow::Result<()> {
        let mut body = Json::obj();
        body.set("name", name.into())
            .set("image", image.into())
            .set("memory", memory.into())
            .set("gpus", (gpus as u64).into());
        let mut l = Json::obj();
        for (k, v) in labels {
            l.set(k, v.as_str().into());
        }
        body.set("labels", l);
        let resp = http::request_with(
            addr,
            "POST",
            "/system/functions",
            &[("Authorization", pwd), ("Content-Type", "application/json")],
            body.to_string().as_bytes(),
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!("deploy {name} on {addr}: {} {}", resp.status, resp.body_str().unwrap_or(""));
        }
        Ok(())
    }

    /// Remove a function through a resource gateway.
    pub fn remove(addr: &str, pwd: &str, name: &str) -> anyhow::Result<()> {
        remove_with(addr, pwd, name, RequestOptions::default())
    }

    /// [`remove`] under an explicit request budget.
    pub fn remove_with(
        addr: &str,
        pwd: &str,
        name: &str,
        opts: RequestOptions,
    ) -> anyhow::Result<()> {
        let mut body = Json::obj();
        body.set("name", name.into());
        let resp = http::request_with(
            addr,
            "DELETE",
            "/system/functions",
            &[("Authorization", pwd), ("Content-Type", "application/json")],
            body.to_string().as_bytes(),
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!("remove {name} on {addr}: {}", resp.status);
        }
        Ok(())
    }

    /// Describe a function; returns the raw JSON document.
    pub fn describe(addr: &str, name: &str) -> anyhow::Result<Json> {
        describe_with(addr, name, RequestOptions::default())
    }

    /// [`describe`] under an explicit request budget.
    pub fn describe_with(addr: &str, name: &str, opts: RequestOptions) -> anyhow::Result<Json> {
        let resp =
            http::request_with(addr, "GET", &format!("/system/function/{name}"), &[], &[], opts)?;
        if !resp.ok() {
            anyhow::bail!("describe {name} on {addr}: {}", resp.status);
        }
        resp.json_body()
    }

    /// Invoke a function synchronously; returns (output, reported latency).
    /// The output shares the response buffer (no copy).
    pub fn invoke(
        addr: &str,
        name: &str,
        payload: &[u8],
    ) -> anyhow::Result<(crate::util::bytes::Bytes, f64)> {
        invoke_with(addr, name, payload, RequestOptions::default())
    }

    /// [`invoke`] under an explicit request budget.
    pub fn invoke_with(
        addr: &str,
        name: &str,
        payload: &[u8],
        opts: RequestOptions,
    ) -> anyhow::Result<(crate::util::bytes::Bytes, f64)> {
        let resp = http::request_with(
            addr,
            "POST",
            &format!("/function/{name}"),
            &[("Content-Type", "application/octet-stream")],
            payload,
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!(
                "invoke {name} on {addr}: {} {}",
                resp.status,
                resp.body_str().unwrap_or("")
            );
        }
        let latency = resp
            .headers
            .get("x-duration-seconds")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.0);
        Ok((resp.body, latency))
    }

    /// Outcome of one wire leg of the `_batch` protocol.
    pub enum BatchAttempt {
        /// The gateway executed the batch: one result per call.
        Ran(Vec<anyhow::Result<(crate::util::bytes::Bytes, f64)>>),
        /// Refused before executing anything (no `_batch` verb, a peer
        /// without this leg's codec, or a pre-wire payload check) —
        /// another leg may be tried safely.
        Refused,
    }

    /// The binary-frame leg of `_batch`
    /// ([`super::BATCH_BINARY_CONTENT_TYPE`]): raw payloads and outputs,
    /// no hex doubling, no UTF-8 requirement. `Refused` on 404 (no verb)
    /// or 400/415 (a JSON-only peer that cannot parse the frames — its
    /// parse-time rejection happens before any execution). Any other
    /// failure is `Err`: the gateway may already have executed the batch,
    /// so retrying — on any leg — would double-execute.
    pub fn invoke_batch_binary(
        addr: &str,
        calls: &[crate::cluster::faas::BatchCall],
    ) -> anyhow::Result<BatchAttempt> {
        invoke_batch_binary_with(addr, calls, RequestOptions::default())
    }

    /// [`invoke_batch_binary`] under an explicit request budget.
    pub fn invoke_batch_binary_with(
        addr: &str,
        calls: &[crate::cluster::faas::BatchCall],
        opts: RequestOptions,
    ) -> anyhow::Result<BatchAttempt> {
        let resp = http::request_with(
            addr,
            "POST",
            "/function/_batch",
            &[("Content-Type", super::BATCH_BINARY_CONTENT_TYPE)],
            &super::encode_binary_calls(calls),
            opts,
        )?;
        if resp.ok() {
            return Ok(BatchAttempt::Ran(super::decode_binary_results(
                &resp.body,
                calls.len(),
            )?));
        }
        if matches!(resp.status, 400 | 404 | 415) {
            return Ok(BatchAttempt::Refused);
        }
        anyhow::bail!(
            "batch invoke on {addr}: {} {}",
            resp.status,
            resp.body_str().unwrap_or("")
        )
    }

    /// The JSON leg of `_batch` (old peers): payloads ride as JSON text,
    /// binary *outputs* come back hex-encoded. `Refused` pre-wire when a
    /// payload is not UTF-8, or on a pre-execution 404/400 from the
    /// gateway; `Err` follows the same may-have-executed rule as the
    /// binary leg.
    pub fn invoke_batch_json(
        addr: &str,
        calls: &[crate::cluster::faas::BatchCall],
    ) -> anyhow::Result<BatchAttempt> {
        invoke_batch_json_with(addr, calls, RequestOptions::default())
    }

    /// [`invoke_batch_json`] under an explicit request budget.
    pub fn invoke_batch_json_with(
        addr: &str,
        calls: &[crate::cluster::faas::BatchCall],
        opts: RequestOptions,
    ) -> anyhow::Result<BatchAttempt> {
        if !calls.iter().all(|c| std::str::from_utf8(&c.payload).is_ok()) {
            return Ok(BatchAttempt::Refused);
        }
        let mut entries = Vec::with_capacity(calls.len());
        for call in calls {
            let text = std::str::from_utf8(&call.payload).expect("checked above");
            let mut o = Json::obj();
            o.set("name", call.name.as_str().into()).set("payload", text.into());
            if call.attempt != 0 {
                // Old gateways ignore unknown fields, so the attempt id
                // rides the JSON leg harmlessly and new gateways dedup.
                o.set("attempt", call.attempt.into());
            }
            entries.push(o);
        }
        let mut body = Json::obj();
        body.set("calls", Json::Arr(entries));
        let resp = http::request_with(
            addr,
            "POST",
            "/function/_batch",
            &[("Content-Type", "application/json")],
            body.to_string().as_bytes(),
            opts,
        )?;
        if resp.status == 404 || resp.status == 400 {
            // Refused before execution: the verb is unknown to this
            // gateway (or the request was rejected at parse time).
            return Ok(BatchAttempt::Refused);
        }
        if !resp.ok() {
            anyhow::bail!(
                "batch invoke on {addr}: {} {}",
                resp.status,
                resp.body_str().unwrap_or("")
            );
        }
        let v = resp.json_body()?;
        let results = v
            .get("results")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("malformed batch response"))?;
        anyhow::ensure!(
            results.len() == calls.len(),
            "batch response arity {} != {} calls",
            results.len(),
            calls.len()
        );
        let decoded = results
            .iter()
            .map(|r| {
                if r.get("ok").and_then(Json::as_bool).unwrap_or(false) {
                    let lat = r.get("latency").and_then(Json::as_f64).unwrap_or(0.0);
                    let out = match r.get("output_hex").and_then(Json::as_str) {
                        Some(hexed) => crate::util::bytes::Bytes::from(super::hex_decode(hexed)?),
                        None => crate::util::bytes::Bytes::from(
                            r.get("output").and_then(Json::as_str).unwrap_or(""),
                        ),
                    };
                    Ok((out, lat))
                } else {
                    let msg =
                        r.get("error").and_then(Json::as_str).unwrap_or("batch entry failed");
                    Err(anyhow::anyhow!(msg.to_string()))
                }
            })
            .collect();
        Ok(BatchAttempt::Ran(decoded))
    }

    /// Invoke a batch of functions in one round trip via `_batch`: the
    /// binary frame leg first, the JSON leg on a pre-execution refusal.
    /// `Ok(Some(results))` carries one result per call; `Ok(None)` means
    /// both legs were refused before executing anything (fall back to
    /// per-call invokes); `Err` means the gateway may already have
    /// executed the batch — do not retry. Callers that talk to the same
    /// gateway repeatedly should use the split legs and cache the peer's
    /// format (see `HttpHandle::invoke_batch`) instead of re-probing
    /// binary every time.
    #[allow(clippy::type_complexity)]
    pub fn invoke_batch(
        addr: &str,
        calls: &[crate::cluster::faas::BatchCall],
    ) -> anyhow::Result<Option<Vec<anyhow::Result<(crate::util::bytes::Bytes, f64)>>>> {
        if let BatchAttempt::Ran(results) = invoke_batch_binary(addr, calls)? {
            return Ok(Some(results));
        }
        match invoke_batch_json(addr, calls)? {
            BatchAttempt::Ran(results) => Ok(Some(results)),
            BatchAttempt::Refused => Ok(None),
        }
    }

    /// List deployed functions.
    pub fn list(addr: &str) -> anyhow::Result<Vec<String>> {
        list_with(addr, RequestOptions::default())
    }

    /// [`list`] under an explicit request budget.
    pub fn list_with(addr: &str, opts: RequestOptions) -> anyhow::Result<Vec<String>> {
        let resp = http::request_with(addr, "GET", "/system/functions", &[], &[], opts)?;
        if !resp.ok() {
            anyhow::bail!("list on {addr}: {}", resp.status);
        }
        let v = resp.json_body()?;
        Ok(v.as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_str().map(String::from))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::faas::NativeExecutor;
    use crate::cluster::spec::ResourceSpec;
    use crate::simnet::RealClock;

    fn gateway() -> (Server, Arc<FaasBackend>) {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        let spec = ResourceSpec::paper_edge("unused");
        let backend = Arc::new(FaasBackend::new(
            spec,
            exec as Arc<dyn super::super::faas::Executor>,
            Arc::new(RealClock::new()),
        ));
        let server = FaasGateway::serve(Arc::clone(&backend), 4).unwrap();
        (server, backend)
    }

    #[test]
    fn full_rest_lifecycle() {
        let (server, _) = gateway();
        let addr = server.addr();
        let pwd = "edgepwd";
        client::deploy(&addr, pwd, "echo", "img/echo", 128 << 20, 0, &[]).unwrap();
        assert_eq!(client::list(&addr).unwrap(), vec!["echo".to_string()]);
        let (out, lat) = client::invoke(&addr, "echo", b"ping").unwrap();
        assert_eq!(out, b"ping");
        assert!(lat >= 0.0);
        let desc = client::describe(&addr, "echo").unwrap();
        assert_eq!(desc.get("invocations").unwrap().as_u64(), Some(1));
        client::remove(&addr, pwd, "echo").unwrap();
        assert!(client::invoke(&addr, "echo", b"x").is_err());
    }

    #[test]
    fn auth_required_for_admin_verbs() {
        let (server, _) = gateway();
        let addr = server.addr();
        assert!(client::deploy(&addr, "wrongpwd", "f", "img/echo", 1 << 20, 0, &[]).is_err());
        // Invoke needs no admin auth (matches OpenFaaS function path).
        client::deploy(&addr, "edgepwd", "f", "img/echo", 1 << 20, 0, &[]).unwrap();
        assert!(client::invoke(&addr, "f", b"x").is_ok());
    }

    #[test]
    fn batch_endpoint_invokes_many_in_one_round_trip() {
        let (server, backend) = gateway();
        let addr = server.addr();
        client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();
        let calls = vec![
            BatchCall::new("echo", Bytes::from("a")),
            BatchCall::new("ghost", Bytes::from("x")),
            BatchCall::new("echo", Bytes::from("b")),
        ];
        let results = client::invoke_batch(&addr, &calls).unwrap().expect("verb supported");
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].as_ref().unwrap().0, &b"a"[..]);
        assert!(results[1].is_err(), "unknown function fails its entry only");
        assert_eq!(results[2].as_ref().unwrap().0, &b"b"[..]);
        assert_eq!(backend.describe("echo").unwrap().invocations, 2);
    }

    #[test]
    fn batch_endpoint_roundtrips_binary_outputs_losslessly() {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/bin", |_: &[u8]| Ok(vec![0xff, 0x00, 0xfe, b'x']));
        let spec = ResourceSpec::paper_edge("unused");
        let backend = Arc::new(FaasBackend::new(
            spec,
            exec as Arc<dyn super::super::faas::Executor>,
            Arc::new(RealClock::new()),
        ));
        let server = FaasGateway::serve(Arc::clone(&backend), 2).unwrap();
        let addr = server.addr();
        client::deploy(&addr, "edgepwd", "bin", "img/bin", 1 << 20, 0, &[]).unwrap();
        let calls = vec![BatchCall::new("bin", Bytes::from("{}"))];
        let results = client::invoke_batch(&addr, &calls).unwrap().expect("verb supported");
        assert_eq!(
            results[0].as_ref().unwrap().0,
            &[0xff, 0x00, 0xfe, b'x'][..],
            "binary output survives the batch wire format"
        );
        assert_eq!(hex_decode(&hex_encode(&[0xde, 0xad, 0x01])).unwrap(), vec![0xde, 0xad, 0x01]);
        assert!(hex_decode("zz").is_err(), "non-hex characters rejected");
        assert!(hex_decode("abc").is_err(), "odd length rejected");
    }

    #[test]
    fn healthz() {
        let (server, _) = gateway();
        let resp = crate::util::http::get(&server.addr(), "/healthz").unwrap();
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn labels_roundtrip() {
        let (server, _) = gateway();
        let addr = server.addr();
        client::deploy(
            &addr,
            "edgepwd",
            "f",
            "img/echo",
            1 << 20,
            0,
            &[("app".to_string(), "videopipeline".to_string())],
        )
        .unwrap();
        let desc = client::describe(&addr, "f").unwrap();
        assert_eq!(desc.get("labels").unwrap().get("app").unwrap().as_str(), Some("videopipeline"));
    }

    fn backend_with(images: &[(&str, fn(&[u8]) -> anyhow::Result<Vec<u8>>)]) -> Arc<FaasBackend> {
        let exec = Arc::new(NativeExecutor::new());
        for (image, f) in images {
            exec.register(image, *f);
        }
        let spec = ResourceSpec::paper_edge("unused");
        Arc::new(FaasBackend::new(
            spec,
            exec as Arc<dyn super::super::faas::Executor>,
            Arc::new(RealClock::new()),
        ))
    }

    #[test]
    fn binary_batch_carries_binary_payloads_and_outputs_raw() {
        let backend =
            backend_with(&[("img/rev", |p: &[u8]| Ok(p.iter().rev().copied().collect()))]);
        let server = FaasGateway::serve(Arc::clone(&backend), 2).unwrap();
        let addr = server.addr();
        client::deploy(&addr, "edgepwd", "rev", "img/rev", 1 << 20, 0, &[]).unwrap();
        // A non-UTF-8 payload: only the binary frame format can carry it
        // in one round trip (the JSON leg would refuse pre-wire).
        let calls = vec![
            BatchCall::new("rev", Bytes::copy_from(&[0xff, 0x00, 0x01])),
            BatchCall::new("ghost", Bytes::from("x")),
        ];
        let results = client::invoke_batch(&addr, &calls).unwrap().expect("binary leg");
        assert_eq!(results[0].as_ref().unwrap().0, &[0x01, 0x00, 0xff][..]);
        assert!(results[1].is_err(), "unknown function fails its entry only");
        assert_eq!(backend.describe("rev").unwrap().invocations, 1);
    }

    #[test]
    fn binary_codec_roundtrips_and_rejects_garbage() {
        let calls = vec![BatchCall {
            name: "f".into(),
            payload: Bytes::copy_from(&[0u8, 159, 146, 150]),
            attempt: 42,
            budget: None,
        }];
        let encoded = encode_binary_calls(&calls);
        // Wire cost: 8 header bytes plus 16 framing bytes per call (8 of
        // them the v2 attempt id) — the 4 payload bytes travel raw, with
        // no hex doubling.
        assert_eq!(encoded.len(), 8 + 8 + (4 + 1) + (4 + 4));
        assert_eq!(&encoded[..4], b"EFB2");
        // Round trip: the v2 decoder recovers the attempt id; a v1 body
        // (no attempt field) decodes as attempt 0.
        let decoded = decode_binary_calls(&Bytes::from(encoded)).unwrap();
        assert_eq!(decoded, calls);
        let mut v1 = Vec::from(&b"EFB1"[..]);
        v1.extend_from_slice(&1u32.to_le_bytes());
        push_blob(&mut v1, b"f");
        push_blob(&mut v1, &[7u8]);
        let legacy = decode_binary_calls(&Bytes::from(v1)).unwrap();
        assert_eq!(legacy[0].name, "f");
        assert_eq!(legacy[0].attempt, 0, "v1 peers get no dedup, not an error");
        let results =
            vec![Ok((Bytes::copy_from(&[0xde, 0xad]), 0.25)), Err(anyhow::anyhow!("boom"))];
        let body = Bytes::from(encode_binary_results(&results));
        let decoded = decode_binary_results(&body, 2).unwrap();
        assert_eq!(decoded[0].as_ref().unwrap().0, &[0xde, 0xad][..]);
        assert_eq!(decoded[0].as_ref().unwrap().1, 0.25);
        // Zero-copy: the decoded output is a window into the response body.
        assert_eq!(
            decoded[0].as_ref().unwrap().0.as_slice().as_ptr(),
            unsafe { body.as_slice().as_ptr().add(8 + 1 + 8 + 4) },
            "output blob shares the wire buffer"
        );
        assert!(decoded[1].as_ref().unwrap_err().to_string().contains("boom"));
        assert!(decode_binary_results(&body, 3).is_err(), "arity checked");
        assert!(decode_binary_results(&Bytes::from(&b"EFB1"[..]), 0).is_err(), "truncated header");
        assert!(
            decode_binary_results(&Bytes::from(&b"NOPE\x00\x00\x00\x00"[..]), 0).is_err(),
            "bad magic"
        );
        // A frame claiming more bytes than the body holds must not panic
        // (or allocate) — it errors.
        let mut bad = Vec::from(&b"EFB1"[..]);
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.push(1);
        bad.extend_from_slice(&0.0f64.to_le_bytes());
        bad.extend_from_slice(&999u32.to_le_bytes());
        assert!(decode_binary_results(&Bytes::from(bad), 1).is_err(), "truncated blob");
    }

    /// A stand-in for an old, JSON-only gateway: refuses the binary batch
    /// content type the way a peer without the codec would (a parse-time
    /// 400, before any execution), forwards everything else.
    struct JsonOnlyPeer(FaasGateway);

    impl Handler for JsonOnlyPeer {
        fn handle(&self, req: Request) -> Response {
            if req.headers.get("content-type").map(String::as_str)
                == Some(BATCH_BINARY_CONTENT_TYPE)
            {
                return Response::bad_request("bad json: unexpected byte".to_string());
            }
            self.0.handle(req)
        }
    }

    #[test]
    fn json_only_peer_gets_the_json_fallback() {
        let backend = backend_with(&[
            ("img/echo", |p: &[u8]| Ok(p.to_vec())),
            ("img/bin", |_: &[u8]| Ok(vec![0xff, 0x00])),
        ]);
        let gw = JsonOnlyPeer(FaasGateway::new(Arc::clone(&backend)));
        let server = Server::bind(0, 2, Arc::new(gw) as Arc<dyn Handler>).unwrap();
        let addr = server.addr();
        client::deploy(&addr, "edgepwd", "echo", "img/echo", 1 << 20, 0, &[]).unwrap();
        client::deploy(&addr, "edgepwd", "bin", "img/bin", 1 << 20, 0, &[]).unwrap();
        // Text payloads ride the JSON leg after the binary refusal; a
        // binary *output* still survives it via the hex encoding.
        let calls = vec![
            BatchCall::new("echo", Bytes::from("hi")),
            BatchCall::new("bin", Bytes::from("{}")),
        ];
        let results = client::invoke_batch(&addr, &calls).unwrap().expect("json leg");
        assert_eq!(results[0].as_ref().unwrap().0, &b"hi"[..]);
        assert_eq!(results[1].as_ref().unwrap().0, &[0xff, 0x00][..]);
        assert_eq!(backend.describe("echo").unwrap().invocations, 1, "executed exactly once");
        // A binary *payload* cannot ride the JSON leg: the client reports
        // "fall back to per-call invokes" without executing anything.
        let calls = vec![BatchCall::new("echo", Bytes::copy_from(&[0xff]))];
        assert!(client::invoke_batch(&addr, &calls).unwrap().is_none());
        assert_eq!(backend.describe("echo").unwrap().invocations, 1);
    }

    #[test]
    fn attempt_ids_dedup_across_the_wire_on_both_legs() {
        let backend = backend_with(&[("img/echo", |p: &[u8]| Ok(p.to_vec()))]);
        let server = FaasGateway::serve(Arc::clone(&backend), 2).unwrap();
        let addr = server.addr();
        client::deploy(&addr, "edgepwd", "echo", "img/echo", 1 << 20, 0, &[]).unwrap();
        let calls = vec![BatchCall {
            name: "echo".into(),
            payload: Bytes::from("hi"),
            attempt: 11,
            budget: None,
        }];
        // Binary leg, twice with the same attempt id: one execution.
        for _ in 0..2 {
            match client::invoke_batch_binary(&addr, &calls).unwrap() {
                client::BatchAttempt::Ran(r) => {
                    assert_eq!(r[0].as_ref().unwrap().0, &b"hi"[..])
                }
                client::BatchAttempt::Refused => panic!("binary leg refused"),
            }
        }
        assert_eq!(backend.describe("echo").unwrap().invocations, 1, "replayed, not re-run");
        // JSON leg with the same attempt id: still the same cached result.
        match client::invoke_batch_json(&addr, &calls).unwrap() {
            client::BatchAttempt::Ran(r) => assert_eq!(r[0].as_ref().unwrap().0, &b"hi"[..]),
            client::BatchAttempt::Refused => panic!("json leg refused"),
        }
        assert_eq!(backend.describe("echo").unwrap().invocations, 1);
    }
}
