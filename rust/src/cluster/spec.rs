//! Resource capability specifications.
//!
//! Mirrors the paper's registration YAML (Table 1) and the testbed's
//! specifications (Table 3). The scheduler's phase-1 filter consumes these
//! capability vectors; the sandbox pool enforces them as capacities.

use crate::simnet::Tier;
use crate::util::bytes::parse_size;
use crate::util::yaml::Yaml;

/// A resource's registered capability (Table 1 fields).
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceSpec {
    /// The paper's `name` field "illustrates the resource's nature":
    /// iot, edge or cloud.
    pub tier: Tier,
    /// Number of physical nodes.
    pub nodes: u32,
    /// Per-node memory in bytes.
    pub memory: u64,
    /// Per-node logical CPU cores.
    pub cpu: u32,
    /// Per-node disk in bytes.
    pub storage: u64,
    /// Number of nodes with GPUs installed.
    pub gpu_nodes: u32,
    /// GPUs per GPU node.
    pub gpus_per_node: u32,
    /// OpenFaaS gateway endpoint (host:port).
    pub gateway: String,
    /// Gateway admin password.
    pub pwd: String,
    /// Prometheus endpoint.
    pub prometheus: String,
    /// MinIO endpoint + credentials.
    pub minio: String,
    pub minio_access_key: String,
    pub minio_secret_key: String,
}

impl ResourceSpec {
    /// Parse a registration YAML document (Table 1 schema).
    pub fn from_yaml(y: &Yaml) -> anyhow::Result<ResourceSpec> {
        let tier = Tier::parse(y.req_str("name")?)?;
        let nodes = y.req_i64("node")? as u32;
        if nodes == 0 {
            anyhow::bail!("resource must have at least one node");
        }
        let memory = parse_size(y.req_str("memory")?)?;
        let cpu = y.req_i64("cpu")? as u32;
        let storage = parse_size(y.req_str("storage")?)?;
        let gpu_nodes = y.get("gpunode").and_then(Yaml::as_i64).unwrap_or(0) as u32;
        let gpus_per_node = y.get("gpu").and_then(Yaml::as_i64).unwrap_or(0) as u32;
        if gpu_nodes > nodes {
            anyhow::bail!("gpunode ({gpu_nodes}) exceeds node count ({nodes})");
        }
        Ok(ResourceSpec {
            tier,
            nodes,
            memory,
            cpu,
            storage,
            gpu_nodes,
            gpus_per_node,
            gateway: y.req_str("gateway")?.to_string(),
            pwd: y.req_str("pwd")?.to_string(),
            prometheus: y.get("prometheus").and_then(Yaml::as_str).unwrap_or("").to_string(),
            minio: y.get("minio").and_then(Yaml::as_str).unwrap_or("").to_string(),
            minio_access_key: y
                .get("minioakey")
                .and_then(Yaml::as_str)
                .unwrap_or("")
                .to_string(),
            minio_secret_key: y
                .get("minioskey")
                .and_then(Yaml::as_str)
                .unwrap_or("")
                .to_string(),
        })
    }

    /// Serialize back to the Table 1 YAML layout.
    pub fn to_yaml(&self) -> String {
        format!(
            "name: {}\nnode: {}\nmemory: {}MB\ncpu: {}\nstorage: {}MB\ngpunode: {}\ngpu: {}\n\
             gateway: {}\npwd: {}\nprometheus: {}\nminio: {}\nminioakey: {}\nminioskey: {}\n",
            self.tier.name(),
            self.nodes,
            self.memory >> 20,
            self.cpu,
            self.storage >> 20,
            self.gpu_nodes,
            self.gpus_per_node,
            self.gateway,
            self.pwd,
            self.prometheus,
            self.minio,
            self.minio_access_key,
            self.minio_secret_key,
        )
    }

    /// Total memory across nodes.
    pub fn total_memory(&self) -> u64 {
        self.memory * self.nodes as u64
    }

    /// Total GPUs across nodes.
    pub fn total_gpus(&self) -> u32 {
        self.gpu_nodes * self.gpus_per_node
    }

    /// Total logical cores across nodes.
    pub fn total_cpus(&self) -> u32 {
        self.cpu * self.nodes
    }

    /// Cold-start latency for a function sandbox on this tier, seconds.
    /// Calibrated to typical faasd-on-Pi vs Kubernetes-on-server numbers.
    pub fn cold_start_s(&self) -> f64 {
        match self.tier {
            Tier::Iot => 1.8,   // faasd + containerd on a Pi 4
            Tier::Edge => 0.9,  // OpenFaaS on a 32-core Xeon
            Tier::Cloud => 0.6, // warm registry, fast NVMe
        }
    }

    /// Relative compute speed factor vs the edge tier for CPU work, and the
    /// GPU acceleration factor for GPU-capable work. Calibrated from the
    /// paper's Fig. 7 (e.g. face detection: 0.433 s on edge vs 0.113 s on
    /// cloud GPU ≈ 3.8×) and from Pi-vs-Xeon single-core ratios.
    pub fn compute_speed(&self, wants_gpu: bool) -> f64 {
        match (self.tier, wants_gpu && self.total_gpus() > 0) {
            (Tier::Iot, _) => 0.08,     // Cortex-A72 vs Xeon
            (Tier::Edge, _) => 1.0,     // reference
            (Tier::Cloud, false) => 1.15,
            (Tier::Cloud, true) => 3.83, // 0.433/0.113 from Fig. 7
        }
    }

    // -------------------------------------------------- Table 3 presets --

    /// The paper's cloud cluster: 10 nodes, 32-core Xeon Silver 4215R,
    /// 512 GB RAM, 512 GB EBS NVMe, 4× RTX 2080 Ti on 8 nodes.
    pub fn paper_cloud(gateway: &str) -> ResourceSpec {
        ResourceSpec {
            tier: Tier::Cloud,
            nodes: 10,
            memory: 512 << 30,
            cpu: 32,
            storage: 512 << 30,
            gpu_nodes: 8,
            gpus_per_node: 4,
            gateway: gateway.to_string(),
            pwd: "cloudpwd".into(),
            prometheus: String::new(),
            minio: String::new(),
            minio_access_key: "minioadmin".into(),
            minio_secret_key: "minioadmin".into(),
        }
    }

    /// The paper's edge cluster: 1 node, 32-core Xeon E5-2630 v3, 64 GB RAM,
    /// 400 GB NVMe, no GPU.
    pub fn paper_edge(gateway: &str) -> ResourceSpec {
        ResourceSpec {
            tier: Tier::Edge,
            nodes: 1,
            memory: 64 << 30,
            cpu: 32,
            storage: 400 << 30,
            gpu_nodes: 0,
            gpus_per_node: 0,
            gateway: gateway.to_string(),
            pwd: "edgepwd".into(),
            prometheus: String::new(),
            minio: String::new(),
            minio_access_key: "minioadmin".into(),
            minio_secret_key: "minioadmin".into(),
        }
    }

    /// A paper IoT device: Raspberry Pi 4B, quad Cortex-A72, 4 GB RAM,
    /// 64 GB SD card, running faasd.
    pub fn paper_iot(gateway: &str) -> ResourceSpec {
        ResourceSpec {
            tier: Tier::Iot,
            nodes: 1,
            memory: 4 << 30,
            cpu: 4,
            storage: 64 << 30,
            gpu_nodes: 0,
            gpus_per_node: 0,
            gateway: gateway.to_string(),
            pwd: "iotpwd".into(),
            prometheus: String::new(),
            minio: String::new(),
            minio_access_key: "minioadmin".into(),
            minio_secret_key: "minioadmin".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::yaml;

    const TABLE1: &str = "\
name: cloud
node: 10
memory: 64GB
cpu: 32
storage: 512GB
gpunode: 8
gpu: 4
gateway: 10.107.30.249:8080
pwd: s2TsHbDfGi
prometheus: 10.107.30.112:30090
minio: 10.107.30.112:9000
minioakey: minioadmin
minioskey: minioadmin
";

    #[test]
    fn parses_table1_sample() {
        let y = yaml::parse(TABLE1).unwrap();
        let spec = ResourceSpec::from_yaml(&y).unwrap();
        assert_eq!(spec.tier, Tier::Cloud);
        assert_eq!(spec.nodes, 10);
        assert_eq!(spec.memory, 64 << 30);
        assert_eq!(spec.total_gpus(), 32);
        assert_eq!(spec.gateway, "10.107.30.249:8080");
        assert_eq!(spec.pwd, "s2TsHbDfGi");
    }

    #[test]
    fn yaml_roundtrip() {
        let y = yaml::parse(TABLE1).unwrap();
        let spec = ResourceSpec::from_yaml(&y).unwrap();
        let text = spec.to_yaml();
        let spec2 = ResourceSpec::from_yaml(&yaml::parse(&text).unwrap()).unwrap();
        assert_eq!(spec, spec2);
    }

    #[test]
    fn rejects_invalid() {
        // zero nodes
        let bad = TABLE1.replace("node: 10", "node: 0");
        assert!(ResourceSpec::from_yaml(&yaml::parse(&bad).unwrap()).is_err());
        // gpunode > node
        let bad = TABLE1.replace("gpunode: 8", "gpunode: 20");
        assert!(ResourceSpec::from_yaml(&yaml::parse(&bad).unwrap()).is_err());
        // unknown tier
        let bad = TABLE1.replace("name: cloud", "name: fog");
        assert!(ResourceSpec::from_yaml(&yaml::parse(&bad).unwrap()).is_err());
        // missing gateway
        let bad = TABLE1.replace("gateway: 10.107.30.249:8080\n", "");
        assert!(ResourceSpec::from_yaml(&yaml::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn presets_match_table3() {
        let cloud = ResourceSpec::paper_cloud("c:8080");
        assert_eq!(cloud.nodes, 10);
        assert_eq!(cloud.total_gpus(), 32);
        let edge = ResourceSpec::paper_edge("e:8080");
        assert_eq!(edge.memory, 64 << 30);
        assert_eq!(edge.total_gpus(), 0);
        let iot = ResourceSpec::paper_iot("i:8080");
        assert_eq!(iot.cpu, 4);
        assert_eq!(iot.memory, 4 << 30);
    }

    #[test]
    fn gpu_speedup_only_with_gpus() {
        let cloud = ResourceSpec::paper_cloud("c");
        let edge = ResourceSpec::paper_edge("e");
        assert!(cloud.compute_speed(true) > 3.0);
        assert!((edge.compute_speed(true) - 1.0).abs() < 1e-9, "no GPU on edge");
        assert!(ResourceSpec::paper_iot("i").compute_speed(false) < 0.2);
    }
}
