//! Function sandbox lifecycle.
//!
//! FaaS platforms "quickly and dynamically scale up and down the number of
//! function sandboxes on demand. As soon as a request finishes, its function
//! sandboxes can be shut down to release resources" (§2.2). This module
//! models exactly that: per-function warm pools with cold-start cost,
//! capacity accounting against the resource's memory/GPU budget, and an idle
//! reaper policy.

use std::collections::HashMap;

/// Resource demands of one sandbox instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SandboxDemand {
    pub memory: u64,
    pub gpus: u32,
}

/// State of a warm pool for one function.
#[derive(Debug, Default)]
struct Pool {
    /// Idle warm sandboxes ready to serve.
    warm: u32,
    /// Sandboxes currently serving a request.
    busy: u32,
    /// Virtual/real timestamp of last use (for the idle reaper).
    last_used: f64,
    demand: Option<SandboxDemand>,
}

/// Outcome of admitting a request into the pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Admission {
    /// A warm sandbox served the request: no startup cost.
    Warm,
    /// A new sandbox was started: pay the cold-start latency.
    Cold,
}

/// Per-resource sandbox manager with capacity accounting.
#[derive(Debug)]
pub struct SandboxManager {
    pools: HashMap<String, Pool>,
    mem_capacity: u64,
    gpu_capacity: u32,
    mem_used: u64,
    gpus_used: u32,
    /// Sandboxes idle longer than this are reaped, seconds.
    pub idle_timeout: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum SandboxError {
    NotDeployed(String),
    Exhausted { need_mem: u64, need_gpu: u32, free_mem: u64, free_gpu: u32 },
}

impl std::fmt::Display for SandboxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SandboxError::NotDeployed(n) => write!(f, "function `{n}` is not deployed"),
            SandboxError::Exhausted { need_mem, need_gpu, free_mem, free_gpu } => write!(
                f,
                "resource exhausted: need {need_mem}B mem / {need_gpu} gpu, \
                 free {free_mem}B / {free_gpu}"
            ),
        }
    }
}

impl std::error::Error for SandboxError {}

impl SandboxManager {
    pub fn new(mem_capacity: u64, gpu_capacity: u32) -> Self {
        SandboxManager {
            pools: HashMap::new(),
            mem_capacity,
            gpu_capacity,
            mem_used: 0,
            gpus_used: 0,
            idle_timeout: 300.0,
        }
    }

    /// Register a function's sandbox demand (at deploy time).
    pub fn register(&mut self, function: &str, demand: SandboxDemand) {
        let pool = self.pools.entry(function.to_string()).or_default();
        pool.demand = Some(demand);
    }

    /// Remove a function and release all its sandboxes.
    pub fn unregister(&mut self, function: &str) {
        if let Some(pool) = self.pools.remove(function) {
            if let Some(d) = pool.demand {
                let n = (pool.warm + pool.busy) as u64;
                self.mem_used = self.mem_used.saturating_sub(d.memory * n);
                self.gpus_used = self.gpus_used.saturating_sub(d.gpus * n as u32);
            }
        }
    }

    /// Admit one request: reuse a warm sandbox or cold-start a new one,
    /// enforcing capacity. `now` is the clock reading (for the reaper).
    pub fn admit(&mut self, function: &str, now: f64) -> Result<Admission, SandboxError> {
        let pool = self
            .pools
            .get_mut(function)
            .ok_or_else(|| SandboxError::NotDeployed(function.to_string()))?;
        let demand = pool.demand.expect("registered pool has demand");
        pool.last_used = now;
        if pool.warm > 0 {
            pool.warm -= 1;
            pool.busy += 1;
            return Ok(Admission::Warm);
        }
        let free_mem = self.mem_capacity - self.mem_used;
        let free_gpu = self.gpu_capacity - self.gpus_used;
        if demand.memory > free_mem || demand.gpus > free_gpu {
            return Err(SandboxError::Exhausted {
                need_mem: demand.memory,
                need_gpu: demand.gpus,
                free_mem,
                free_gpu,
            });
        }
        self.mem_used += demand.memory;
        self.gpus_used += demand.gpus;
        pool.busy += 1;
        Ok(Admission::Cold)
    }

    /// Admit one request for each of `functions` in a single call — the
    /// batch entry behind `FaasBackend::invoke_batch`'s one-lock-pass
    /// admission. Results line up with `functions`; each element has
    /// exactly the semantics of calling [`SandboxManager::admit`] in that
    /// order (earlier admissions in the batch consume capacity seen by
    /// later ones), and a failed admission leaves the others untouched.
    pub fn admit_batch(
        &mut self,
        functions: &[&str],
        now: f64,
    ) -> Vec<Result<Admission, SandboxError>> {
        functions.iter().map(|f| self.admit(f, now)).collect()
    }

    /// Complete one request: the sandbox returns to the warm pool.
    pub fn release(&mut self, function: &str, now: f64) {
        if let Some(pool) = self.pools.get_mut(function) {
            assert!(pool.busy > 0, "release without admit for `{function}`");
            pool.busy -= 1;
            pool.warm += 1;
            pool.last_used = now;
        }
    }

    /// Reap warm sandboxes idle past `idle_timeout`; returns reaped count.
    pub fn reap_idle(&mut self, now: f64) -> u32 {
        let timeout = self.idle_timeout;
        let mut reaped = 0;
        for pool in self.pools.values_mut() {
            if pool.warm > 0 && now - pool.last_used > timeout {
                if let Some(d) = pool.demand {
                    self.mem_used = self.mem_used.saturating_sub(d.memory * pool.warm as u64);
                    self.gpus_used = self.gpus_used.saturating_sub(d.gpus * pool.warm);
                }
                reaped += pool.warm;
                pool.warm = 0;
            }
        }
        reaped
    }

    /// Current replica count (warm + busy) for a function.
    pub fn replicas(&self, function: &str) -> u32 {
        self.pools.get(function).map(|p| p.warm + p.busy).unwrap_or(0)
    }

    pub fn mem_used(&self) -> u64 {
        self.mem_used
    }

    pub fn gpus_used(&self) -> u32 {
        self.gpus_used
    }

    /// Fraction of memory capacity in use (feeds the Prometheus stand-in).
    pub fn mem_utilization(&self) -> f64 {
        if self.mem_capacity == 0 {
            0.0
        } else {
            self.mem_used as f64 / self.mem_capacity as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1 << 20;

    fn mgr() -> SandboxManager {
        let mut m = SandboxManager::new(1024 * MB, 2);
        m.register("f", SandboxDemand { memory: 256 * MB, gpus: 0 });
        m
    }

    #[test]
    fn first_request_is_cold_then_warm() {
        let mut m = mgr();
        assert_eq!(m.admit("f", 0.0).unwrap(), Admission::Cold);
        m.release("f", 1.0);
        assert_eq!(m.admit("f", 2.0).unwrap(), Admission::Warm);
        assert_eq!(m.replicas("f"), 1);
    }

    #[test]
    fn concurrency_scales_out() {
        let mut m = mgr();
        for _ in 0..4 {
            assert_eq!(m.admit("f", 0.0).unwrap(), Admission::Cold);
        }
        assert_eq!(m.replicas("f"), 4);
        assert_eq!(m.mem_used(), 1024 * MB);
        // Capacity is now exhausted.
        assert!(matches!(m.admit("f", 0.0), Err(SandboxError::Exhausted { .. })));
    }

    #[test]
    fn gpu_accounting() {
        let mut m = SandboxManager::new(1 << 40, 2);
        m.register("g", SandboxDemand { memory: MB, gpus: 1 });
        m.admit("g", 0.0).unwrap();
        m.admit("g", 0.0).unwrap();
        assert_eq!(m.gpus_used(), 2);
        assert!(m.admit("g", 0.0).is_err(), "only 2 GPUs");
        m.unregister("g");
        assert_eq!(m.gpus_used(), 0);
    }

    #[test]
    fn batch_admission_matches_sequential_order() {
        let mut m = SandboxManager::new(640 * MB, 2);
        m.register("f", SandboxDemand { memory: 256 * MB, gpus: 0 });
        m.register("g", SandboxDemand { memory: 256 * MB, gpus: 0 });
        // Warm one `f` sandbox so the batch sees a mixed warm/cold pool.
        m.admit("f", 0.0).unwrap();
        m.release("f", 0.0);
        let out = m.admit_batch(&["f", "g", "f", "missing"], 1.0);
        assert_eq!(out[0], Ok(Admission::Warm), "reuses the warm sandbox");
        assert_eq!(out[1], Ok(Admission::Cold));
        assert_eq!(out[2], Ok(Admission::Cold), "second f cold-starts");
        assert!(matches!(out[3], Err(SandboxError::NotDeployed(_))));
        // Capacity drained by the batch exactly as sequential admits would:
        // 3 × 256 MB busy, 640 MB cap → the next admit is refused.
        assert!(matches!(m.admit("g", 1.0), Err(SandboxError::Exhausted { .. })));
        assert_eq!(m.replicas("f"), 2);
        assert_eq!(m.replicas("g"), 1);
    }

    #[test]
    fn undeployed_function_rejected() {
        let mut m = mgr();
        assert!(matches!(m.admit("nope", 0.0), Err(SandboxError::NotDeployed(_))));
    }

    #[test]
    fn reaper_frees_idle_sandboxes() {
        let mut m = mgr();
        m.idle_timeout = 10.0;
        m.admit("f", 0.0).unwrap();
        m.release("f", 1.0);
        assert_eq!(m.reap_idle(5.0), 0, "not idle long enough");
        assert_eq!(m.reap_idle(12.0), 1);
        assert_eq!(m.replicas("f"), 0);
        assert_eq!(m.mem_used(), 0);
        // Next request cold-starts again.
        assert_eq!(m.admit("f", 13.0).unwrap(), Admission::Cold);
    }

    #[test]
    fn utilization_fraction() {
        let mut m = mgr();
        assert_eq!(m.mem_utilization(), 0.0);
        m.admit("f", 0.0).unwrap();
        assert!((m.mem_utilization() - 0.25).abs() < 1e-9);
    }

    /// Property: after any interleaving of admit/release/reap, accounting
    /// never goes negative and never exceeds capacity.
    #[test]
    fn prop_accounting_invariants() {
        let mut rng = crate::util::rng::Pcg32::seeded(99);
        let mut m = SandboxManager::new(512 * MB, 4);
        m.idle_timeout = 5.0;
        for f in ["a", "b", "c"] {
            m.register(
                f,
                SandboxDemand {
                    memory: (64 + 64 * rng.next_below(3) as u64) * MB,
                    gpus: rng.next_below(2),
                },
            );
        }
        let funcs = ["a", "b", "c"];
        let mut outstanding: Vec<&str> = Vec::new();
        let mut now = 0.0;
        for _ in 0..2000 {
            now += rng.next_f64();
            match rng.next_below(4) {
                0 | 1 => {
                    let f = *rng.choose(&funcs);
                    if m.admit(f, now).is_ok() {
                        outstanding.push(f);
                    }
                }
                2 => {
                    if !outstanding.is_empty() {
                        let i = rng.range(0, outstanding.len());
                        let f = outstanding.swap_remove(i);
                        m.release(f, now);
                    }
                }
                _ => {
                    m.reap_idle(now);
                }
            }
            assert!(m.mem_used() <= 512 * MB, "mem within capacity");
            assert!(m.gpus_used() <= 4, "gpus within capacity");
        }
    }
}
