//! The FaaS backend proper — the per-resource OpenFaaS/faasd stand-in.
//!
//! EdgeFaaS "deploys functions on the resource to utilize the resource"
//! through each resource's gateway (§3.1). This backend implements the verbs
//! that gateway exposes: deploy, remove, describe, list, invoke — with the
//! sandbox/capacity model of [`super::sandbox`] underneath and an
//! [`Executor`] doing the actual compute.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::simnet::Clock;
use crate::util::bytes::Bytes;

use super::sandbox::{Admission, SandboxDemand, SandboxManager};
use super::spec::ResourceSpec;

/// Deployment-time function specification (the paper's deployment package
/// plus the Table 2 `requirements`).
#[derive(Debug, Clone, PartialEq)]
pub struct FunctionSpec {
    pub name: String,
    /// Image / package reference (the `.zip` code property in the paper).
    /// `Arc<str>` so the per-invocation hot path clones a refcount, not the
    /// string.
    pub image: Arc<str>,
    /// Required memory per sandbox, bytes.
    pub memory: u64,
    /// Required GPUs per sandbox.
    pub gpus: u32,
    /// Opaque labels (EdgeFaaS stores its application name here).
    pub labels: HashMap<String, String>,
}

/// Runtime description of a deployed function (OpenFaaS `describe`).
#[derive(Debug, Clone)]
pub struct FunctionStatus {
    pub spec: FunctionSpec,
    pub replicas: u32,
    pub invocations: u64,
    /// URL path the function is invocable at on this gateway.
    pub url: String,
}

/// Executes the body of a function. Implementations:
/// [`NativeExecutor`] (rust closures → PJRT compute) for the real path, and
/// the perf-model executor for virtual-time benches.
///
/// Payloads travel as shared [`Bytes`]: the engine hands every placement of
/// a node the same envelope buffer, and handlers can return a shared buffer
/// without the runtime re-materializing it.
pub trait Executor: Send + Sync {
    /// Run `function` with `payload`, returning its output bytes.
    fn execute(&self, function: &str, payload: &Bytes) -> anyhow::Result<Bytes>;

    /// Estimated execution seconds (virtual-time mode); `None` means "run
    /// [`execute`](Executor::execute) for real and use wall time".
    fn model_latency(&self, _function: &str, _payload_len: usize) -> Option<f64> {
        None
    }
}

/// A registered handler body (zero-copy form).
type BytesHandler = Arc<dyn Fn(&Bytes) -> anyhow::Result<Bytes> + Send + Sync>;

/// Registry of rust closures keyed by function image name.
#[derive(Default)]
pub struct NativeExecutor {
    handlers: Mutex<HashMap<String, BytesHandler>>,
}

impl NativeExecutor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a slice-based handler for a function image (the common
    /// form: most handlers parse the envelope and build a fresh response).
    pub fn register<F>(&self, image: &str, f: F)
    where
        F: Fn(&[u8]) -> anyhow::Result<Vec<u8>> + Send + Sync + 'static,
    {
        self.register_bytes(image, move |p: &Bytes| f(p.as_slice()).map(Bytes::from));
    }

    /// Register a zero-copy handler: takes and returns shared [`Bytes`], so
    /// a handler can hand back a precomputed or sliced buffer without
    /// allocating per invocation (the hot-path benches use this).
    pub fn register_bytes<F>(&self, image: &str, f: F)
    where
        F: Fn(&Bytes) -> anyhow::Result<Bytes> + Send + Sync + 'static,
    {
        self.handlers.lock().unwrap().insert(image.to_string(), Arc::new(f));
    }
}

impl Executor for NativeExecutor {
    fn execute(&self, function: &str, payload: &Bytes) -> anyhow::Result<Bytes> {
        let handler = {
            let map = self.handlers.lock().unwrap();
            map.get(function).cloned()
        };
        match handler {
            Some(h) => h(payload),
            None => anyhow::bail!("no handler registered for image `{function}`"),
        }
    }
}

/// One entry of the backend's `Batch` verb: function name, payload, and the
/// engine-assigned attempt id used for at-most-once retry deduplication.
///
/// Attempt `0` means "no dedup" (ad-hoc callers, pre-liveness peers on the
/// wire). Nonzero ids are engine-global and unique per instance attempt:
/// if a coordinator retries an instance whose first send actually executed
/// here (the reply was lost, or the resource flapped), the re-sent attempt
/// id hits this backend's [attempt cache](FaasBackend::invoke_batch) and the
/// recorded result is replayed instead of executing the function twice.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchCall {
    pub name: String,
    pub payload: Bytes,
    pub attempt: u64,
    /// Client-side deadline budget for the batch carrying this call (the
    /// engine derives it from the run's QoS deadline). Never serialized on
    /// the wire — wire-decoded calls carry `None` — it only shapes the
    /// sending [`HttpHandle`](crate::coordinator::handle::HttpHandle)'s
    /// request deadline.
    pub budget: Option<std::time::Duration>,
}

impl BatchCall {
    /// An undeduplicated call (attempt 0) — the pre-liveness behaviour.
    pub fn new(name: impl Into<String>, payload: Bytes) -> Self {
        BatchCall { name: name.into(), payload, attempt: 0, budget: None }
    }
}

/// Bounded FIFO memory of executed attempt ids → recorded results. Sized so
/// a retry storm cannot grow a backend without bound; ids are unique
/// (engine-global counter), so eviction order is insertion order.
const ATTEMPT_CACHE_CAP: usize = 1024;

#[derive(Default)]
struct AttemptCache {
    map: HashMap<u64, Result<(Bytes, f64), String>>,
    order: VecDeque<u64>,
}

impl AttemptCache {
    fn record(&mut self, attempt: u64, result: Result<(Bytes, f64), String>) {
        if self.map.insert(attempt, result).is_none() {
            self.order.push_back(attempt);
            while self.order.len() > ATTEMPT_CACHE_CAP {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
        }
    }
}

#[derive(Debug)]
pub enum FaasError {
    AlreadyDeployed(String),
    NotFound(String),
    Insufficient(String, String),
}

impl std::fmt::Display for FaasError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaasError::AlreadyDeployed(n) => write!(f, "function `{n}` already deployed"),
            FaasError::NotFound(n) => write!(f, "function `{n}` not found"),
            FaasError::Insufficient(n, why) => {
                write!(f, "insufficient resources for `{n}`: {why}")
            }
        }
    }
}

impl std::error::Error for FaasError {}

struct Inner {
    functions: HashMap<String, FunctionStatus>,
    sandboxes: SandboxManager,
}

/// One resource's FaaS backend (thread-safe).
pub struct FaasBackend {
    pub spec: ResourceSpec,
    inner: Mutex<Inner>,
    executor: Arc<dyn Executor>,
    clock: Arc<dyn Clock>,
    /// Executed attempt ids → recorded results (the at-most-once dedup
    /// memory; see [`BatchCall`]). Separate lock from `inner`: a replay hit
    /// never touches sandbox state.
    attempts: Mutex<AttemptCache>,
    /// `inner`-lock acquisitions — observability for the batch admission
    /// fast path (see [`FaasBackend::inner_lock_acquisitions`]).
    inner_locks: AtomicU64,
}

impl FaasBackend {
    pub fn new(spec: ResourceSpec, executor: Arc<dyn Executor>, clock: Arc<dyn Clock>) -> Self {
        let sandboxes = SandboxManager::new(spec.total_memory(), spec.total_gpus());
        FaasBackend {
            spec,
            inner: Mutex::new(Inner { functions: HashMap::new(), sandboxes }),
            executor,
            clock,
            attempts: Mutex::new(AttemptCache::default()),
            inner_locks: AtomicU64::new(0),
        }
    }

    /// Take the status/sandbox lock, counting the acquisition.
    fn lock_inner(&self) -> MutexGuard<'_, Inner> {
        self.inner_locks.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap()
    }

    /// Total status/sandbox-lock acquisitions over this backend's life.
    /// A `Batch` verb takes the lock exactly twice — one bulk admission
    /// pass, one bulk release pass — however many calls it carries; unit
    /// tests pin that contract here.
    pub fn inner_lock_acquisitions(&self) -> u64 {
        self.inner_locks.load(Ordering::Relaxed)
    }

    /// Deploy a function. Fails if already present or if a single sandbox of
    /// it could never fit this resource (the paper's phase-1 criterion
    /// enforced locally too).
    pub fn deploy(&self, spec: FunctionSpec) -> Result<(), FaasError> {
        let mut inner = self.lock_inner();
        if inner.functions.contains_key(&spec.name) {
            return Err(FaasError::AlreadyDeployed(spec.name));
        }
        if spec.memory > self.spec.total_memory() {
            return Err(FaasError::Insufficient(
                spec.name.clone(),
                format!("needs {}B memory, have {}B", spec.memory, self.spec.total_memory()),
            ));
        }
        if spec.gpus > self.spec.total_gpus() {
            return Err(FaasError::Insufficient(
                spec.name.clone(),
                format!("needs {} GPUs, have {}", spec.gpus, self.spec.total_gpus()),
            ));
        }
        inner
            .sandboxes
            .register(&spec.name, SandboxDemand { memory: spec.memory, gpus: spec.gpus });
        let url = format!("/function/{}", spec.name);
        inner
            .functions
            .insert(spec.name.clone(), FunctionStatus { spec, replicas: 0, invocations: 0, url });
        Ok(())
    }

    /// Remove a function and free its sandboxes.
    pub fn remove(&self, name: &str) -> Result<(), FaasError> {
        let mut inner = self.lock_inner();
        if inner.functions.remove(name).is_none() {
            return Err(FaasError::NotFound(name.to_string()));
        }
        inner.sandboxes.unregister(name);
        Ok(())
    }

    /// Describe a deployed function.
    pub fn describe(&self, name: &str) -> Result<FunctionStatus, FaasError> {
        let inner = self.lock_inner();
        let mut st = inner
            .functions
            .get(name)
            .cloned()
            .ok_or_else(|| FaasError::NotFound(name.to_string()))?;
        st.replicas = inner.sandboxes.replicas(name);
        Ok(st)
    }

    /// List deployed function names (sorted, deterministic).
    pub fn list(&self) -> Vec<String> {
        let inner = self.lock_inner();
        let mut names: Vec<String> = inner.functions.keys().cloned().collect();
        names.sort();
        names
    }

    /// Invoke a function synchronously. Applies sandbox admission (cold vs
    /// warm), runs the executor, releases the sandbox, and returns
    /// `(output, total_latency_s)`. In virtual-time mode the latency comes
    /// from the executor's model and the clock is advanced instead of slept.
    ///
    /// Hot-path note: the invocation bump and the image lookup happen in
    /// one `get_mut` pass, and the image is an `Arc<str>` clone (refcount
    /// bump) — nothing string-sized is copied while the status lock is
    /// held.
    pub fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        let image: Arc<str>;
        let admission;
        {
            let mut inner = self.lock_inner();
            let st = inner
                .functions
                .get_mut(name)
                .ok_or_else(|| FaasError::NotFound(name.to_string()))?;
            st.invocations += 1;
            image = Arc::clone(&st.spec.image);
            let now = self.clock.now();
            admission = inner
                .sandboxes
                .admit(name, now)
                .map_err(|e| FaasError::Insufficient(name.to_string(), e.to_string()))?;
        }
        let (result, elapsed) =
            self.execute_body(&image, payload, matches!(admission, Admission::Cold));
        {
            let mut inner = self.lock_inner();
            inner.sandboxes.release(name, self.clock.now());
        }
        let out = result?;
        Ok((out, elapsed))
    }

    /// Run the executor for one admitted call: cold-start sleep (when the
    /// admission was cold), model-latency sleep in virtual-time mode, then
    /// the handler. Returns the handler result and the observed latency —
    /// shared by [`FaasBackend::invoke`] and the batch path.
    fn execute_body(&self, image: &str, payload: &Bytes, cold: bool) -> (anyhow::Result<Bytes>, f64) {
        let start = self.clock.now();
        if cold {
            self.clock.sleep(self.spec.cold_start_s());
        }
        let result = match self.executor.model_latency(image, payload.len()) {
            Some(model_s) => {
                self.clock.sleep(model_s);
                self.executor.execute(image, payload)
            }
            None => self.executor.execute(image, payload),
        };
        (result, self.clock.now() - start)
    }

    /// The backend protocol's `Batch` verb: invoke several functions in one
    /// call, sequentially, returning one result per entry.
    ///
    /// Admission is cross-function and bulk: one status-lock pass resolves
    /// every call, bumps invocation counters, and admits **one sandbox per
    /// distinct function** via [`SandboxManager::admit_batch`]; a second
    /// pass releases them after the last call ran. Two status-lock
    /// acquisitions per batch, total, however many calls it carries
    /// ([`FaasBackend::inner_lock_acquisitions`] exposes the count and a
    /// unit test pins it). Capacity behaviour is unchanged from the old
    /// admit-per-call loop: releasing a sandbox returns it to the warm pool
    /// *without freeing its memory*, so a sequential batch already held one
    /// sandbox's worth of capacity per distinct function by the time it
    /// finished — bulk admission merely claims the same footprint up
    /// front. A refused admission fails every call of that function with
    /// [`FaasError::Insufficient`]; the first executed call of a
    /// cold-admitted function pays the cold start, later calls of it run
    /// warm (exactly as sequential admits would behave).
    ///
    /// A panicking handler fails its own entry only; later entries still
    /// run, and the function's sandbox is still released at the end of the
    /// batch.
    ///
    /// Nonzero attempt ids are deduplicated (at-most-once per backend): an
    /// attempt that already executed here replays its recorded result —
    /// success *or* failure — instead of running the handler again, so a
    /// coordinator retrying past a lost reply cannot double-execute. The
    /// record is bounded ([`ATTEMPT_CACHE_CAP`], FIFO by first execution).
    pub fn invoke_batch(&self, calls: &[BatchCall]) -> Vec<anyhow::Result<(Bytes, f64)>> {
        let mut out: Vec<Option<anyhow::Result<(Bytes, f64)>>> = Vec::with_capacity(calls.len());
        out.resize_with(calls.len(), || None);
        let mut replayed = vec![false; calls.len()];
        // Pass 1: replay already-executed attempts under one cache lock.
        {
            let cache = self.attempts.lock().unwrap();
            for (i, call) in calls.iter().enumerate() {
                if call.attempt == 0 {
                    continue;
                }
                if let Some(hit) = cache.map.get(&call.attempt) {
                    replayed[i] = true;
                    out[i] = Some(match hit {
                        Ok((bytes, lat)) => Ok((bytes.clone(), *lat)),
                        Err(e) => Err(anyhow::anyhow!("{e}")),
                    });
                }
            }
        }
        // Pass 2: one status-lock pass — resolve names, bump counters, and
        // bulk-admit one sandbox per distinct function (first-call order).
        let mut images: Vec<Option<Arc<str>>> = vec![None; calls.len()];
        let mut fn_of_call: Vec<usize> = vec![usize::MAX; calls.len()];
        let mut names: Vec<&str> = Vec::new();
        let admissions;
        {
            let mut inner = self.lock_inner();
            for (i, call) in calls.iter().enumerate() {
                if out[i].is_some() {
                    continue;
                }
                match inner.functions.get_mut(&call.name) {
                    None => {
                        out[i] = Some(Err(FaasError::NotFound(call.name.clone()).into()));
                    }
                    Some(st) => {
                        st.invocations += 1;
                        images[i] = Some(Arc::clone(&st.spec.image));
                        fn_of_call[i] = names
                            .iter()
                            .position(|n| *n == call.name.as_str())
                            .unwrap_or_else(|| {
                                names.push(call.name.as_str());
                                names.len() - 1
                            });
                    }
                }
            }
            let now = self.clock.now();
            admissions = inner.sandboxes.admit_batch(&names, now);
        }
        let admitted: Vec<bool> = admissions.iter().map(Result::is_ok).collect();
        let mut cold_pending: Vec<bool> =
            admissions.iter().map(|a| matches!(a, Ok(Admission::Cold))).collect();
        // Pass 3: run the calls sequentially outside the lock.
        for (i, call) in calls.iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            let f = fn_of_call[i];
            if let Err(e) = &admissions[f] {
                out[i] =
                    Some(Err(FaasError::Insufficient(call.name.clone(), e.to_string()).into()));
                continue;
            }
            let image = images[i].as_ref().expect("admitted call resolved an image");
            let cold = std::mem::replace(&mut cold_pending[f], false);
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.execute_body(image, &call.payload, cold)
            }));
            out[i] = Some(match run {
                Ok((Ok(bytes), lat)) => Ok((bytes, lat)),
                Ok((Err(e), _)) => Err(e),
                Err(p) => Err(anyhow::anyhow!(
                    "function handler panicked: {}",
                    crate::util::panic_message(&*p)
                )),
            });
        }
        // Pass 4: one release pass — each admitted sandbox back to warm.
        if admitted.iter().any(|a| *a) {
            let mut inner = self.lock_inner();
            let now = self.clock.now();
            for (f, name) in names.iter().enumerate() {
                if admitted[f] {
                    inner.sandboxes.release(name, now);
                }
            }
        }
        // Pass 5: record fresh attempt outcomes under one cache lock.
        if calls.iter().enumerate().any(|(i, c)| c.attempt != 0 && !replayed[i]) {
            let mut cache = self.attempts.lock().unwrap();
            for (i, call) in calls.iter().enumerate() {
                if call.attempt == 0 || replayed[i] {
                    continue;
                }
                let recorded = match out[i].as_ref().expect("call resolved") {
                    Ok((bytes, lat)) => Ok((bytes.clone(), *lat)),
                    Err(e) => Err(e.to_string()),
                };
                cache.record(call.attempt, recorded);
            }
        }
        out.into_iter().map(|r| r.expect("every batch entry resolved")).collect()
    }

    /// Memory utilization fraction (scraped by the monitoring substrate).
    pub fn mem_utilization(&self) -> f64 {
        self.lock_inner().sandboxes.mem_utilization()
    }

    /// Reap idle sandboxes (OpenFaaS's scale-to-zero behaviour).
    pub fn reap_idle(&self) -> u32 {
        let now = self.clock.now();
        self.lock_inner().sandboxes.reap_idle(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{RealClock, VirtualClock};

    fn bp(p: &[u8]) -> Bytes {
        Bytes::copy_from(p)
    }

    fn backend() -> (FaasBackend, Arc<NativeExecutor>) {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        exec.register("img/upper", |p: &[u8]| Ok(p.to_ascii_uppercase()));
        let spec = ResourceSpec::paper_edge("127.0.0.1:0");
        let b = FaasBackend::new(spec, exec.clone() as Arc<dyn Executor>, Arc::new(RealClock::new()));
        (b, exec)
    }

    fn fspec(name: &str, image: &str) -> FunctionSpec {
        FunctionSpec {
            name: name.into(),
            image: image.into(),
            memory: 256 << 20,
            gpus: 0,
            labels: HashMap::new(),
        }
    }

    #[test]
    fn deploy_invoke_remove_cycle() {
        let (b, _) = backend();
        b.deploy(fspec("echo", "img/echo")).unwrap();
        let (out, _lat) = b.invoke("echo", &bp(b"hello")).unwrap();
        assert_eq!(out, &b"hello"[..]);
        let st = b.describe("echo").unwrap();
        assert_eq!(st.invocations, 1);
        assert_eq!(st.replicas, 1, "sandbox stays warm after release");
        b.remove("echo").unwrap();
        assert!(b.invoke("echo", &bp(b"x")).is_err());
    }

    #[test]
    fn duplicate_deploy_rejected() {
        let (b, _) = backend();
        b.deploy(fspec("f", "img/echo")).unwrap();
        assert!(matches!(b.deploy(fspec("f", "img/echo")), Err(FaasError::AlreadyDeployed(_))));
    }

    #[test]
    fn oversized_function_rejected() {
        let (b, _) = backend();
        let mut f = fspec("big", "img/echo");
        f.memory = 1 << 50;
        assert!(matches!(b.deploy(f), Err(FaasError::Insufficient(..))));
        let mut g = fspec("gpu", "img/echo");
        g.gpus = 1;
        assert!(matches!(b.deploy(g), Err(FaasError::Insufficient(..))), "edge has no GPU");
    }

    #[test]
    fn list_is_sorted() {
        let (b, _) = backend();
        b.deploy(fspec("zeta", "img/echo")).unwrap();
        b.deploy(fspec("alpha", "img/upper")).unwrap();
        assert_eq!(b.list(), vec!["alpha".to_string(), "zeta".to_string()]);
    }

    #[test]
    fn missing_image_errors_cleanly() {
        let (b, _) = backend();
        b.deploy(fspec("ghost", "img/none")).unwrap();
        assert!(b.invoke("ghost", &bp(b"")).is_err());
        // Sandbox must have been released despite the error.
        let st = b.describe("ghost").unwrap();
        assert_eq!(st.replicas, 1);
        assert!(b.invoke("ghost", &bp(b"")).is_err(), "stays invocable (and failing)");
    }

    #[test]
    fn virtual_clock_cold_start_accounting() {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        let clock = Arc::new(VirtualClock::new());
        let spec = ResourceSpec::paper_iot("127.0.0.1:0");
        let cold = spec.cold_start_s();
        let b = FaasBackend::new(spec, exec as Arc<dyn Executor>, clock.clone());
        b.deploy(fspec("echo", "img/echo")).unwrap();
        let (_, lat1) = b.invoke("echo", &bp(b"x")).unwrap();
        assert!((lat1 - cold).abs() < 1e-6, "first call pays cold start: {lat1}");
        let (_, lat2) = b.invoke("echo", &bp(b"x")).unwrap();
        assert!(lat2 < 1e-6, "warm call is instant in virtual time: {lat2}");
    }

    #[test]
    fn invoke_batch_matches_sequential_invokes() {
        let (b, exec) = backend();
        exec.register("img/boom", |_: &[u8]| -> anyhow::Result<Vec<u8>> { panic!("kapow") });
        b.deploy(fspec("echo", "img/echo")).unwrap();
        b.deploy(fspec("upper", "img/upper")).unwrap();
        b.deploy(fspec("boom", "img/boom")).unwrap();
        let calls = vec![
            BatchCall::new("echo", Bytes::from("one")),
            BatchCall::new("upper", Bytes::from("two")),
            BatchCall::new("boom", Bytes::new()),
            BatchCall::new("missing", Bytes::new()),
            BatchCall::new("echo", Bytes::from("three")),
        ];
        let results = b.invoke_batch(&calls);
        assert_eq!(results.len(), 5);
        assert_eq!(results[0].as_ref().unwrap().0, &b"one"[..]);
        assert_eq!(results[1].as_ref().unwrap().0, &b"TWO"[..]);
        let err = results[2].as_ref().unwrap_err().to_string();
        assert!(err.contains("kapow"), "panic contained to its entry: {err}");
        assert!(results[3].is_err(), "unknown function fails its own entry");
        assert_eq!(results[4].as_ref().unwrap().0, &b"three"[..], "later entries still run");
        assert_eq!(b.describe("echo").unwrap().invocations, 2);
        let boom = b.describe("boom").unwrap();
        assert_eq!(boom.replicas, 1, "panicked function's sandbox still released to warm");
    }

    #[test]
    fn batch_takes_the_inner_lock_exactly_twice() {
        let (b, _) = backend();
        b.deploy(fspec("echo", "img/echo")).unwrap();
        b.deploy(fspec("upper", "img/upper")).unwrap();
        let calls = vec![
            BatchCall::new("echo", Bytes::from("a")),
            BatchCall::new("upper", Bytes::from("b")),
            BatchCall::new("echo", Bytes::from("c")),
            BatchCall::new("missing", Bytes::new()),
        ];
        let before = b.inner_lock_acquisitions();
        let results = b.invoke_batch(&calls);
        assert_eq!(
            b.inner_lock_acquisitions() - before,
            2,
            "one bulk admission pass + one bulk release pass, regardless of batch size"
        );
        assert!(results[0].is_ok() && results[1].is_ok() && results[2].is_ok());
        assert!(results[3].is_err(), "unknown function resolved without extra locking");
        // The equivalent sequential invokes take two lock passes *each*.
        let before = b.inner_lock_acquisitions();
        b.invoke("echo", &bp(b"a")).unwrap();
        b.invoke("upper", &bp(b"b")).unwrap();
        b.invoke("echo", &bp(b"c")).unwrap();
        assert_eq!(b.inner_lock_acquisitions() - before, 6);
    }

    #[test]
    fn repeated_attempt_id_replays_instead_of_reexecuting() {
        let (b, exec) = backend();
        exec.register("img/fail", |_: &[u8]| -> anyhow::Result<Vec<u8>> {
            anyhow::bail!("transient")
        });
        b.deploy(fspec("echo", "img/echo")).unwrap();
        b.deploy(fspec("fail", "img/fail")).unwrap();
        let call =
            BatchCall { name: "echo".into(), payload: Bytes::from("x"), attempt: 7, budget: None };
        let first = b.invoke_batch(std::slice::from_ref(&call));
        assert_eq!(first[0].as_ref().unwrap().0, &b"x"[..]);
        // Same attempt id again: replay, no second execution.
        let second = b.invoke_batch(&[call]);
        assert_eq!(second[0].as_ref().unwrap().0, &b"x"[..]);
        assert_eq!(b.describe("echo").unwrap().invocations, 1, "executed once");
        // Failures replay too — at-most-once covers both outcomes.
        let boom =
            BatchCall { name: "fail".into(), payload: Bytes::new(), attempt: 8, budget: None };
        let e1 = b.invoke_batch(std::slice::from_ref(&boom));
        assert!(e1[0].is_err());
        let e2 = b.invoke_batch(&[boom]);
        assert!(e2[0].as_ref().unwrap_err().to_string().contains("transient"));
        assert_eq!(b.describe("fail").unwrap().invocations, 1);
        // Attempt 0 never deduplicates.
        let plain = BatchCall::new("echo", Bytes::from("y"));
        b.invoke_batch(std::slice::from_ref(&plain));
        b.invoke_batch(&[plain]);
        assert_eq!(b.describe("echo").unwrap().invocations, 3);
    }

    #[test]
    fn concurrent_invocations() {
        let (b, _) = backend();
        b.deploy(fspec("echo", "img/echo")).unwrap();
        let b = Arc::new(b);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    let payload = format!("req{i}");
                    let (out, _) = b.invoke("echo", &bp(payload.as_bytes())).unwrap();
                    assert_eq!(out, payload.as_bytes());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(b.describe("echo").unwrap().invocations, 8);
    }
}
