//! Monitoring substrate (the Prometheus stand-in).
//!
//! "Each resource has a Prometheus service deployed to monitor the resource
//! usages... CPU usage, memory usage, I/O bandwidth and GPU usage" (§3.1.2).
//! [`metrics`] is the per-resource gauge/counter registry, [`scrape`] is the
//! text exposition endpoint plus the scraper client EdgeFaaS uses during
//! phase-1 scheduling.

pub mod metrics;
pub mod scrape;

pub use metrics::{MetricsRegistry, ResourceUsage};
