//! Monitoring substrate (the Prometheus stand-in).
//!
//! "Each resource has a Prometheus service deployed to monitor the resource
//! usages... CPU usage, memory usage, I/O bandwidth and GPU usage" (§3.1.2).
//! [`metrics`] is the per-resource gauge/counter registry, [`scrape`] is the
//! text exposition endpoint plus the scraper client.
//!
//! [`snapshot`] is the **monitoring snapshot plane**: a background
//! collector scrapes every registered resource and publishes an
//! epoch-versioned, atomically-swapped [`snapshot::MonitorSnapshot`]
//! (usage samples with a staleness bound, plus a dense latency matrix
//! lifted from the topology), so the two-phase scheduler's decisions are
//! pure in-memory reads instead of O(resources) synchronous scrapes — see
//! the [`snapshot`] module docs for epoching, staleness, and the
//! collector lifecycle.
//!
//! [`liveness`] turns the collector into a **failure detector**: each sweep
//! advances a per-resource lease (`Alive` → `Suspect` → `Dead` →
//! `Recovering`), published alongside the usage samples in every snapshot.
//! The coordinator acts on the transitions (drain, candidate exclusion,
//! relocation, quarantined re-admission) — see the [`liveness`] module docs
//! for the state machine.

pub mod liveness;
pub mod metrics;
pub mod scrape;
pub mod snapshot;

pub use liveness::{LeaseState, LivenessConfig, ResourceLease};
pub use metrics::{MetricsRegistry, ResourceUsage};
pub use snapshot::{LatencyMatrix, MonitorSnapshot, SnapshotPlane, UsageSample};
