//! Prometheus scrape endpoint + scraper client.
//!
//! Each resource serves `GET /metrics` in the Prometheus text exposition
//! format; "EdgeFaaS fetches the Prometheus resource metrics from each
//! resource" (§3.1.2) with [`scrape`].

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::util::http::{request_with, Handler, Request, RequestOptions, Response, Server};

use super::metrics::{MetricsRegistry, ResourceUsage};

/// HTTP facade exposing one registry at `/metrics`.
pub struct MetricsGateway {
    registry: Arc<MetricsRegistry>,
}

impl MetricsGateway {
    pub fn serve(registry: Arc<MetricsRegistry>) -> anyhow::Result<Server> {
        let gw = Arc::new(MetricsGateway { registry });
        Server::bind(0, 2, gw as Arc<dyn Handler>)
    }
}

impl Handler for MetricsGateway {
    fn handle(&self, req: Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/metrics") => Response::text(200, self.registry.exposition()),
            ("GET", "/healthz") => Response::text(200, "ok"),
            _ => Response::not_found(),
        }
    }
}

/// Parse a Prometheus text exposition into name → value. Labelled series are
/// keyed as `name{labels}`.
///
/// Exposition lines are `name value [timestamp]` with arbitrary whitespace
/// between fields: the value is the *first* numeric field after the metric
/// name, never the trailing timestamp. The name ends at the closing `}` of
/// its label set (label values may contain spaces) or, unlabelled, at the
/// first whitespace.
pub fn parse_exposition(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // The value/timestamp tail is numeric and cannot contain `}`, so
        // the last `}` on the line closes the label set.
        let (name, rest) = match line.rfind('}') {
            Some(close) => line.split_at(close + 1),
            None => match line.split_once(char::is_whitespace) {
                Some((name, rest)) => (name, rest),
                None => continue,
            },
        };
        let mut fields = rest.split_whitespace();
        if let Some(v) = fields.next().and_then(|f| f.parse::<f64>().ok()) {
            out.insert(name.to_string(), v);
        }
    }
    out
}

/// Why a scrape failed — the classification the liveness detector's
/// `last_error` surfaces. `Unreachable` is connection-level death (refused,
/// reset, timed out: the strongest churn signal); `Bad` is a resource that
/// answered but wrongly (HTTP error status or a non-UTF-8 body) — still a
/// missed heartbeat, but pointing at a misbehaving exporter rather than a
/// dead box.
#[derive(Debug)]
pub enum ScrapeFailure {
    Unreachable { addr: String, cause: String },
    Bad { addr: String, cause: String },
}

impl std::fmt::Display for ScrapeFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScrapeFailure::Unreachable { addr, cause } => {
                write!(f, "scrape {addr} unreachable: {cause}")
            }
            ScrapeFailure::Bad { addr, cause } => {
                write!(f, "scrape {addr} bad response: {cause}")
            }
        }
    }
}

impl std::error::Error for ScrapeFailure {}

/// Scrape a resource's `/metrics` endpoint and decode the standard usage
/// vector. Rides the shared pooled HTTP client, so periodic scrapes of the
/// same endpoint (the snapshot collector's steady-state) reuse one
/// keep-alive connection instead of a fresh TCP handshake per tick.
///
/// Failures are typed [`ScrapeFailure`]s (downcastable from the returned
/// `anyhow::Error`), so the liveness plane's `last_error` distinguishes a
/// dead box from a confused exporter.
pub fn scrape(addr: &str) -> anyhow::Result<ResourceUsage> {
    scrape_with(addr, RequestOptions::default())
}

/// [`scrape`] under an explicit request budget — the liveness plane probes
/// with a tight deadline so a partitioned exporter costs one budget, not a
/// socket default.
pub fn scrape_with(addr: &str, opts: RequestOptions) -> anyhow::Result<ResourceUsage> {
    let resp = request_with(addr, "GET", "/metrics", &[], &[], opts).map_err(|e| {
        ScrapeFailure::Unreachable { addr: addr.to_string(), cause: e.to_string() }
    })?;
    if !resp.ok() {
        anyhow::bail!(ScrapeFailure::Bad {
            addr: addr.to_string(),
            cause: format!("status {}", resp.status),
        });
    }
    let body = resp.body_str().map_err(|e| ScrapeFailure::Bad {
        addr: addr.to_string(),
        cause: e.to_string(),
    })?;
    let series = parse_exposition(body);
    let g = |name: &str| series.get(&format!("edgefaas_{name}")).copied().unwrap_or(0.0);
    Ok(ResourceUsage {
        cpu_frac: g("node_cpu_usage"),
        mem_used: g("node_memory_used_bytes") as u64,
        mem_total: g("node_memory_total_bytes") as u64,
        io_bytes_per_s: g("node_io_bytes_per_second"),
        gpu_frac: g("node_gpu_usage"),
        gpus_used: g("node_gpus_used") as u32,
        gpus_total: g("node_gpus_total") as u32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrape_roundtrip() {
        let registry = Arc::new(MetricsRegistry::new());
        let usage = ResourceUsage {
            cpu_frac: 0.6,
            mem_used: 2 << 30,
            mem_total: 64 << 30,
            io_bytes_per_s: 5e6,
            gpu_frac: 0.0,
            gpus_used: 0,
            gpus_total: 0,
        };
        registry.record_usage(&usage);
        let server = MetricsGateway::serve(registry).unwrap();
        let scraped = scrape(&server.addr()).unwrap();
        assert_eq!(scraped, usage);
    }

    #[test]
    fn parse_skips_comments_and_junk() {
        let text = "# HELP x y\n# TYPE a gauge\na 1.5\nbad line without value x\nb{l=\"v\"} 2\n\n";
        let m = parse_exposition(text);
        assert_eq!(m.get("a"), Some(&1.5));
        assert_eq!(m.get("b{l=\"v\"}"), Some(&2.0));
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn missing_endpoint_is_error() {
        let err = scrape("127.0.0.1:1").unwrap_err();
        assert!(
            matches!(err.downcast_ref(), Some(ScrapeFailure::Unreachable { .. })),
            "connection-level death is typed Unreachable: {err}"
        );
    }

    #[test]
    fn http_error_status_is_typed_bad_not_unreachable() {
        // A server that answers — just not with metrics. /metrics 404s.
        struct NoMetrics;
        impl Handler for NoMetrics {
            fn handle(&self, _req: Request) -> Response {
                Response::not_found()
            }
        }
        let server = Server::bind(0, 1, Arc::new(NoMetrics) as Arc<dyn Handler>).unwrap();
        let err = scrape(&server.addr()).unwrap_err();
        assert!(
            matches!(err.downcast_ref(), Some(ScrapeFailure::Bad { .. })),
            "an answering-but-wrong exporter is Bad, not Unreachable: {err}"
        );
    }

    #[test]
    fn parse_takes_the_value_not_the_trailing_timestamp() {
        // `name value timestamp` lines: the value is the first numeric
        // field after the name, never the timestamp.
        let text = "a 1.5 1395066363000\n\
                    b{l=\"v\"} 2 1395066363000\n\
                    c   3.25    1395066363000\n\
                    d\t4\t1395066363000\n\
                    spaced{l=\"two words\"} 5 1395066363000\n";
        let m = parse_exposition(text);
        assert_eq!(m.get("a"), Some(&1.5));
        assert_eq!(m.get("b{l=\"v\"}"), Some(&2.0));
        assert_eq!(m.get("c"), Some(&3.25), "multi-space separators");
        assert_eq!(m.get("d"), Some(&4.0), "tab separators");
        assert_eq!(m.get("spaced{l=\"two words\"}"), Some(&5.0), "label value with a space");
        assert_eq!(m.len(), 5);
    }
}
