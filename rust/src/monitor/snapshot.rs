//! The monitoring snapshot plane — epoch-versioned, atomically-swapped
//! cluster state for the scheduling fast path.
//!
//! §3.1.2 has EdgeFaaS "fetch the Prometheus resource metrics from each
//! resource" during phase-1 scheduling — a synchronous scrape per resource
//! per decision, O(resources) network round trips on the exact path the
//! two-phase scheduler (§3.2.3) exercises under load. The snapshot plane
//! moves those scrapes *off* the decision path:
//!
//! * A **[`MonitorSnapshot`]** is an immutable point-in-time view: one
//!   [`UsageSample`] per registered resource (the scraped usage vector plus
//!   the clock time it was collected) and a dense **[`LatencyMatrix`]**
//!   lifted from the network topology (all-pairs one-way latencies, one
//!   Dijkstra sweep per node instead of a per-pair search on every
//!   placement comparison).
//!
//! * The **[`SnapshotPlane`]** publishes snapshots behind an
//!   `RwLock<Arc<MonitorSnapshot>>`: readers clone the `Arc` (a refcount
//!   bump under a read lock held for nanoseconds) and then work entirely
//!   on immutable data; a refresh builds the next snapshot *outside* any
//!   lock and swaps the pointer in one write. Every publish bumps the
//!   **epoch** — the version number the coordinator's placement decision
//!   cache is keyed by, so cached decisions are invalidated exactly when
//!   the monitoring view changes.
//!
//! * **Staleness bound.** Each sample carries `collected_at`; consumers
//!   (the phase-1 filter) treat samples older than the plane's `max_age`
//!   as missing and fall back to a direct scrape of that one resource —
//!   the snapshot accelerates the common case without ever feeding the
//!   scheduler data older than the bound. With no collector running the
//!   snapshot is empty and every decision degrades to exactly the old
//!   per-call-scrape behaviour.
//!
//! * **Collector lifecycle.** The refresh loop itself lives in the
//!   coordinator (`EdgeFaaS::start_monitor_collector`): a background
//!   thread that re-scrapes every registered resource and publishes, then
//!   `Clock::sleep`s the refresh interval — clock-generic, so the same
//!   collector runs under `RealClock` (examples, gateways) and
//!   `VirtualClock` (tests, benches). The plane only tracks the collector's
//!   stop flag so exactly one collector runs at a time and
//!   `stop_monitor_collector` can end it.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::simnet::Topology;

use super::liveness::ResourceLease;
use super::metrics::ResourceUsage;

/// Default staleness bound, seconds: snapshot samples older than this are
/// treated as missing (phase-1 falls back to a direct scrape).
pub const DEFAULT_SNAPSHOT_MAX_AGE_S: f64 = 5.0;

/// One resource's scraped usage vector plus when it was collected
/// (coordinator clock seconds).
///
/// When a sweep fails to scrape a resource, the collector carries the
/// previous usage vector forward but bumps `consecutive_failures` and
/// records `last_error` — `collected_at` stays at the last *successful*
/// scrape, so the [`MonitorSnapshot::fresh_usage_of`] staleness bound
/// naturally ages a failing resource out of the fast path while the
/// failure counters make the staleness visible (`GET /monitor/snapshot`)
/// instead of silently serving the last-good sample forever.
#[derive(Debug, Clone, PartialEq)]
pub struct UsageSample {
    pub usage: ResourceUsage,
    /// Clock time of the last successful scrape.
    pub collected_at: f64,
    /// Consecutive sweeps whose scrape of this resource failed (0 when the
    /// latest sweep succeeded).
    pub consecutive_failures: u32,
    /// The most recent scrape error, if the latest sweep failed.
    pub last_error: Option<String>,
}

impl UsageSample {
    /// A sample from a successful scrape at `now`.
    pub fn fresh(usage: ResourceUsage, now: f64) -> UsageSample {
        UsageSample { usage, collected_at: now, consecutive_failures: 0, last_error: None }
    }
}

/// Dense all-pairs one-way latency matrix over the topology's nodes.
///
/// Built with one Dijkstra sweep per source node
/// ([`Topology::latencies_from`]); lookups are a single indexed load, so
/// placement policies comparing hundreds of candidates never re-run a
/// shortest-path search. Out-of-range nodes read as `INFINITY`, matching
/// [`Topology::latency`] for disconnected pairs.
#[derive(Debug, Clone, Default)]
pub struct LatencyMatrix {
    n: usize,
    data: Vec<f64>,
}

impl LatencyMatrix {
    /// An empty matrix (every lookup is `INFINITY`).
    pub fn empty() -> LatencyMatrix {
        LatencyMatrix::default()
    }

    /// Lift the full topology into a dense matrix.
    pub fn from_topology(topo: &Topology) -> LatencyMatrix {
        let n = topo.len();
        let mut data = Vec::with_capacity(n * n);
        for from in 0..n {
            data.extend(topo.latencies_from(from));
        }
        LatencyMatrix { n, data }
    }

    /// Number of topology nodes covered.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// One-way latency between two topology nodes, seconds (`INFINITY`
    /// when either node is out of range or the pair is disconnected).
    pub fn latency(&self, from: usize, to: usize) -> f64 {
        if from < self.n && to < self.n {
            self.data[from * self.n + to]
        } else {
            f64::INFINITY
        }
    }
}

/// An immutable point-in-time view of cluster state: per-resource usage
/// samples plus the dense latency matrix. Shared as `Arc<MonitorSnapshot>`;
/// consumers never lock while reading it.
#[derive(Debug, Clone)]
pub struct MonitorSnapshot {
    /// Version number, bumped on every publish. Epoch 0 is the empty
    /// initial snapshot (no collector has ever run).
    pub epoch: u64,
    /// Coordinator clock time the snapshot was published.
    pub taken_at: f64,
    usage: BTreeMap<u32, UsageSample>,
    /// Per-resource failure-detector leases (see [`super::liveness`]).
    /// Empty until a collector sweep runs.
    leases: BTreeMap<u32, ResourceLease>,
    latency: Arc<LatencyMatrix>,
}

impl MonitorSnapshot {
    /// The initial (epoch-0) snapshot: no usage samples, the given matrix.
    pub fn initial(latency: Arc<LatencyMatrix>) -> MonitorSnapshot {
        MonitorSnapshot {
            epoch: 0,
            taken_at: 0.0,
            usage: BTreeMap::new(),
            leases: BTreeMap::new(),
            latency,
        }
    }

    /// The sample for one resource, if any was ever collected.
    pub fn usage_of(&self, resource: u32) -> Option<&UsageSample> {
        self.usage.get(&resource)
    }

    /// The usage vector for one resource *iff* its sample is no older than
    /// `max_age` at clock time `now` — the staleness-bounded read the
    /// phase-1 filter performs (a `None` means "scrape directly").
    pub fn fresh_usage_of(&self, resource: u32, now: f64, max_age: f64) -> Option<&ResourceUsage> {
        self.usage
            .get(&resource)
            .filter(|s| now - s.collected_at <= max_age)
            .map(|s| &s.usage)
    }

    /// All samples, ascending resource id.
    pub fn samples(&self) -> impl Iterator<Item = (u32, &UsageSample)> {
        self.usage.iter().map(|(k, v)| (*k, v))
    }

    /// The failure-detector lease for one resource, if a sweep ever ran.
    /// A missing lease means the detector has no opinion — consumers treat
    /// it as schedulable (the pre-liveness behaviour).
    pub fn lease_of(&self, resource: u32) -> Option<&ResourceLease> {
        self.leases.get(&resource)
    }

    /// All leases, ascending resource id.
    pub fn leases(&self) -> impl Iterator<Item = (u32, &ResourceLease)> {
        self.leases.iter().map(|(k, v)| (*k, v))
    }

    /// Number of resources with a sample.
    pub fn len(&self) -> usize {
        self.usage.len()
    }

    pub fn is_empty(&self) -> bool {
        self.usage.is_empty()
    }

    /// The dense latency matrix (always present, even at epoch 0).
    pub fn latencies(&self) -> &LatencyMatrix {
        &self.latency
    }

    /// Shared handle to the matrix (refcount bump).
    pub fn latencies_arc(&self) -> Arc<LatencyMatrix> {
        Arc::clone(&self.latency)
    }

    /// Owned copies of the usage and lease tables — the scratch state for
    /// publishers that edit a few entries and re-publish (data-path miss
    /// reports, federation gossip merges).
    pub fn clone_tables(&self) -> (BTreeMap<u32, UsageSample>, BTreeMap<u32, ResourceLease>) {
        (self.usage.clone(), self.leases.clone())
    }
}

/// The publication point: the current snapshot, its epoch, the staleness
/// bound, and the running collector's stop flag (at most one collector).
pub struct SnapshotPlane {
    current: RwLock<Arc<MonitorSnapshot>>,
    epoch: AtomicU64,
    /// Staleness bound in integer nanoseconds (atomic f64 stand-in).
    max_age_ns: AtomicU64,
    collector_stop: Mutex<Option<Arc<AtomicBool>>>,
}

impl SnapshotPlane {
    /// A plane whose epoch-0 snapshot carries `latency` and no samples.
    pub fn new(latency: Arc<LatencyMatrix>) -> SnapshotPlane {
        SnapshotPlane {
            current: RwLock::new(Arc::new(MonitorSnapshot::initial(latency))),
            epoch: AtomicU64::new(0),
            max_age_ns: AtomicU64::new((DEFAULT_SNAPSHOT_MAX_AGE_S * 1e9) as u64),
            collector_stop: Mutex::new(None),
        }
    }

    /// The current snapshot (refcount bump under a read lock).
    pub fn snapshot(&self) -> Arc<MonitorSnapshot> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// The current epoch without touching the snapshot lock.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// The staleness bound, seconds.
    pub fn max_age(&self) -> f64 {
        self.max_age_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Set the staleness bound (clamped to >= 0).
    pub fn set_max_age(&self, max_age_s: f64) {
        let ns = if max_age_s > 0.0 { (max_age_s * 1e9) as u64 } else { 0 };
        self.max_age_ns.store(ns, Ordering::Relaxed);
    }

    /// Publish a new snapshot: bump the epoch and swap the pointer.
    /// Returns the new epoch. The epoch is assigned *under* the write
    /// lock, so concurrent publishers (the collector racing a direct
    /// refresh) install snapshots in strictly increasing epoch order —
    /// the visible snapshot can never regress to an older epoch.
    pub fn publish(
        &self,
        usage: BTreeMap<u32, UsageSample>,
        leases: BTreeMap<u32, ResourceLease>,
        latency: Arc<LatencyMatrix>,
        now: f64,
    ) -> u64 {
        let mut cur = self.current.write().unwrap();
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        *cur = Arc::new(MonitorSnapshot { epoch, taken_at: now, usage, leases, latency });
        epoch
    }

    /// Register a collector's stop flag. Returns `false` (and leaves the
    /// existing collector alone) when one is already running.
    pub fn register_collector(&self, stop: Arc<AtomicBool>) -> bool {
        let mut slot = self.collector_stop.lock().unwrap();
        match &*slot {
            Some(existing) if !existing.load(Ordering::SeqCst) => false,
            _ => {
                *slot = Some(stop);
                true
            }
        }
    }

    /// Whether a collector is currently registered and not stopped.
    pub fn collector_running(&self) -> bool {
        self.collector_stop
            .lock()
            .unwrap()
            .as_ref()
            .map(|s| !s.load(Ordering::SeqCst))
            .unwrap_or(false)
    }

    /// Signal the running collector (if any) to stop after its current
    /// cycle. Does not block on the collector thread.
    pub fn stop_collector(&self) {
        if let Some(stop) = self.collector_stop.lock().unwrap().take() {
            stop.store(true, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{Tier, Topology};

    fn topo() -> Topology {
        let mut t = Topology::new();
        let a = t.add_node("a", Tier::Iot);
        let b = t.add_node("b", Tier::Edge);
        let c = t.add_node("c", Tier::Cloud);
        t.add_link(a, b, 0.002, 1e6);
        t.add_link(b, c, 0.010, 1e6);
        t
    }

    #[test]
    fn matrix_matches_topology_latency() {
        let t = topo();
        let m = LatencyMatrix::from_topology(&t);
        assert_eq!(m.len(), 3);
        for from in 0..3 {
            for to in 0..3 {
                assert!(
                    (m.latency(from, to) - t.latency(from, to)).abs() < 1e-12,
                    "{from}->{to}"
                );
            }
        }
        assert!(m.latency(0, 99).is_infinite());
        assert!(LatencyMatrix::empty().latency(0, 0).is_infinite());
    }

    #[test]
    fn publish_bumps_epoch_and_swaps_atomically() {
        let m = Arc::new(LatencyMatrix::from_topology(&topo()));
        let plane = SnapshotPlane::new(Arc::clone(&m));
        assert_eq!(plane.epoch(), 0);
        assert!(plane.snapshot().is_empty());
        let old = plane.snapshot();
        let mut usage = BTreeMap::new();
        usage.insert(7u32, UsageSample::fresh(ResourceUsage::default(), 1.5));
        let mut leases = BTreeMap::new();
        leases.insert(7u32, ResourceLease::alive(1.5));
        let e = plane.publish(usage, leases, m, 1.5);
        assert_eq!(e, 1);
        assert_eq!(plane.epoch(), 1);
        // The old Arc is still a valid (immutable) epoch-0 view.
        assert_eq!(old.epoch, 0);
        assert!(old.is_empty());
        assert!(old.lease_of(7).is_none());
        let new = plane.snapshot();
        assert_eq!(new.epoch, 1);
        assert!(new.usage_of(7).is_some());
        assert_eq!(new.usage_of(7).unwrap().consecutive_failures, 0);
        assert!(new.lease_of(7).is_some());
    }

    #[test]
    fn freshness_is_bounded_by_max_age() {
        let m = Arc::new(LatencyMatrix::empty());
        let plane = SnapshotPlane::new(Arc::clone(&m));
        let mut usage = BTreeMap::new();
        usage.insert(1u32, UsageSample::fresh(ResourceUsage::default(), 10.0));
        plane.publish(usage, BTreeMap::new(), m, 10.0);
        let snap = plane.snapshot();
        assert!(snap.fresh_usage_of(1, 12.0, 5.0).is_some(), "2s old, bound 5s");
        assert!(snap.fresh_usage_of(1, 16.0, 5.0).is_none(), "6s old, bound 5s");
        assert!(snap.fresh_usage_of(2, 10.0, 5.0).is_none(), "never sampled");
    }

    #[test]
    fn one_collector_at_a_time() {
        let plane = SnapshotPlane::new(Arc::new(LatencyMatrix::empty()));
        assert!(!plane.collector_running());
        let s1 = Arc::new(AtomicBool::new(false));
        assert!(plane.register_collector(Arc::clone(&s1)));
        assert!(plane.collector_running());
        assert!(!plane.register_collector(Arc::new(AtomicBool::new(false))));
        plane.stop_collector();
        assert!(s1.load(Ordering::SeqCst), "stop flag raised");
        assert!(!plane.collector_running());
        // A stopped slot can be replaced.
        assert!(plane.register_collector(Arc::new(AtomicBool::new(false))));
    }
}
