//! Gauge/counter registry per resource.
//!
//! The phase-1 scheduler "fetches the Prometheus resource metrics from each
//! resource and picks out resources that can meet the minimum resource
//! requirement of the function" (§3.1.2). The registry tracks exactly the
//! usage vector that decision needs, plus per-node load distribution.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Point-in-time usage of one resource (fractions in [0,1], bytes for mem).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceUsage {
    pub cpu_frac: f64,
    pub mem_used: u64,
    pub mem_total: u64,
    pub io_bytes_per_s: f64,
    pub gpu_frac: f64,
    pub gpus_used: u32,
    pub gpus_total: u32,
}

impl ResourceUsage {
    pub fn mem_free(&self) -> u64 {
        self.mem_total.saturating_sub(self.mem_used)
    }

    pub fn gpus_free(&self) -> u32 {
        self.gpus_total.saturating_sub(self.gpus_used)
    }
}

/// Thread-safe metrics registry: named gauges/counters plus per-node load.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    gauges: BTreeMap<String, f64>,
    counters: BTreeMap<String, u64>,
    /// Per-node CPU load (the paper: "Prometheus also monitors the load
    /// distribution of all the nodes that belong to one resource").
    node_load: BTreeMap<String, f64>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set_gauge(&self, name: &str, value: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn inc_counter(&self, name: &str, by: u64) {
        *self.inner.lock().unwrap().counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn set_node_load(&self, node: &str, load: f64) {
        self.inner.lock().unwrap().node_load.insert(node.to_string(), load);
    }

    /// Record the standard usage vector.
    pub fn record_usage(&self, u: &ResourceUsage) {
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.insert("node_cpu_usage".into(), u.cpu_frac);
        inner.gauges.insert("node_memory_used_bytes".into(), u.mem_used as f64);
        inner.gauges.insert("node_memory_total_bytes".into(), u.mem_total as f64);
        inner.gauges.insert("node_io_bytes_per_second".into(), u.io_bytes_per_s);
        inner.gauges.insert("node_gpu_usage".into(), u.gpu_frac);
        inner.gauges.insert("node_gpus_used".into(), u.gpus_used as f64);
        inner.gauges.insert("node_gpus_total".into(), u.gpus_total as f64);
    }

    /// Read back the standard usage vector.
    pub fn usage(&self) -> ResourceUsage {
        let inner = self.inner.lock().unwrap();
        let g = |name: &str| inner.gauges.get(name).copied().unwrap_or(0.0);
        ResourceUsage {
            cpu_frac: g("node_cpu_usage"),
            mem_used: g("node_memory_used_bytes") as u64,
            mem_total: g("node_memory_total_bytes") as u64,
            io_bytes_per_s: g("node_io_bytes_per_second"),
            gpu_frac: g("node_gpu_usage"),
            gpus_used: g("node_gpus_used") as u32,
            gpus_total: g("node_gpus_total") as u32,
        }
    }

    /// Prometheus text exposition of every metric.
    pub fn exposition(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &inner.gauges {
            out.push_str(&format!("# TYPE edgefaas_{k} gauge\nedgefaas_{k} {v}\n"));
        }
        for (k, v) in &inner.counters {
            out.push_str(&format!("# TYPE edgefaas_{k} counter\nedgefaas_{k} {v}\n"));
        }
        for (node, load) in &inner.node_load {
            out.push_str(&format!("edgefaas_node_load{{node=\"{node}\"}} {load}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauges_and_counters() {
        let m = MetricsRegistry::new();
        m.set_gauge("node_cpu_usage", 0.42);
        assert_eq!(m.gauge("node_cpu_usage"), Some(0.42));
        m.inc_counter("invocations_total", 3);
        m.inc_counter("invocations_total", 2);
        assert_eq!(m.counter("invocations_total"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn usage_roundtrip() {
        let m = MetricsRegistry::new();
        let u = ResourceUsage {
            cpu_frac: 0.3,
            mem_used: 1 << 30,
            mem_total: 4 << 30,
            io_bytes_per_s: 1e6,
            gpu_frac: 0.5,
            gpus_used: 2,
            gpus_total: 4,
        };
        m.record_usage(&u);
        assert_eq!(m.usage(), u);
        assert_eq!(u.mem_free(), 3 << 30);
        assert_eq!(u.gpus_free(), 2);
    }

    #[test]
    fn exposition_format() {
        let m = MetricsRegistry::new();
        m.set_gauge("node_cpu_usage", 0.25);
        m.inc_counter("requests_total", 7);
        m.set_node_load("node-1", 0.8);
        let text = m.exposition();
        assert!(text.contains("edgefaas_node_cpu_usage 0.25"));
        assert!(text.contains("edgefaas_requests_total 7"));
        assert!(text.contains("edgefaas_node_load{node=\"node-1\"} 0.8"));
        assert!(text.contains("# TYPE edgefaas_node_cpu_usage gauge"));
    }
}
