//! The failure detector behind the liveness plane — per-resource lease
//! state driven by the monitor collector's scrape sweeps.
//!
//! Production edge fleets flap: the paper's own IoT tier (Raspberry Pis on
//! home networks) is the least reliable hardware in the system. The
//! snapshot collector already touches every resource once per sweep, so
//! each sweep doubles as a heartbeat: a successful scrape renews the
//! resource's lease, a failed one counts against it.
//!
//! # Lease states
//!
//! ```text
//!            miss                 miss (total >= dead_after)
//!   Alive ---------> Suspect --------------------------------> Dead
//!     ^                 |                                       |
//!     |      scrape ok  |                             scrape ok |
//!     +-----------------+                                       v
//!     ^                                                    Recovering
//!     |        clean sweeps >= quarantine_sweeps                |
//!     +---------------------------------------------------------+
//! ```
//!
//! * **Alive** — the last sweep scraped successfully. The resource is a
//!   full scheduling citizen.
//! * **Suspect** — at least one consecutive sweep missed. Still scheduled,
//!   but the engine treats invocation failures against a Suspect resource
//!   as infrastructure failures (eligible for the at-most-once retry path)
//!   rather than application errors.
//! * **Dead** — `dead_after` consecutive sweeps missed. The coordinator
//!   drains the resource's queued instances, removes it from candidate
//!   mappings, and relocates its functions; the scheduler's phase-1 filter
//!   excludes it.
//! * **Recovering** — a Dead resource answered a scrape again. It stays
//!   quarantined (excluded from scheduling) until `quarantine_sweeps`
//!   consecutive clean sweeps pass, then it is re-admitted and its
//!   candidate memberships restored. A miss during quarantine sends it
//!   straight back to Dead (no second drain — it was never re-admitted).
//!
//! # Evidence sources
//!
//! Sweeps are not the only heartbeat. Live traffic reports too: a
//! connectivity-class failure (connect refused/timed out, request
//! deadline, reset, truncation — see `util::http::HttpError`) on an
//! invoke, object transfer, or scrape is fed back as a **data-path miss**
//! (`EdgeFaaS::report_data_path_miss`), stepping the same state machine
//! between sweeps. A fully partitioned resource therefore turns Suspect
//! from the first request that hits the partition — before the detector's
//! next pass — and repeated data-path misses can mark it Dead outright.
//! Only sweeps renew a lease (`ok = false` evidence can never readmit),
//! so data-path reports only ever accelerate detection.
//!
//! The state machine itself ([`step`]) is a pure function of (config,
//! previous lease, sweep outcome, now) so chaos tests can drive it
//! deterministically under `VirtualClock`; the side effects (drain,
//! candidate exclusion, relocation, re-admission) live in the coordinator
//! (`EdgeFaaS::refresh_monitor_snapshot` and
//! `EdgeFaaS::report_data_path_miss`), keyed off the [`Transition`]s
//! this module reports.

/// Configuration of the failure detector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LivenessConfig {
    /// Consecutive missed sweeps before a resource is marked Dead.
    /// (1 missed sweep already makes it Suspect.)
    pub dead_after: u32,
    /// Consecutive clean sweeps a recovering resource must answer before
    /// it is re-admitted to scheduling.
    pub quarantine_sweeps: u32,
}

impl Default for LivenessConfig {
    fn default() -> Self {
        LivenessConfig { dead_after: 3, quarantine_sweeps: 2 }
    }
}

/// One resource's lease state (see the module docs for the lifecycle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeaseState {
    Alive,
    Suspect,
    Dead,
    Recovering,
}

impl LeaseState {
    pub fn as_str(&self) -> &'static str {
        match self {
            LeaseState::Alive => "alive",
            LeaseState::Suspect => "suspect",
            LeaseState::Dead => "dead",
            LeaseState::Recovering => "recovering",
        }
    }

    /// Whether the scheduler may place onto / dispatch to this resource.
    /// Suspect resources remain schedulable (one missed scrape is routine);
    /// Dead and quarantined (Recovering) ones do not.
    pub fn schedulable(&self) -> bool {
        matches!(self, LeaseState::Alive | LeaseState::Suspect)
    }

    /// Parse the lowercase wire name (inverse of [`Self::as_str`]) — the
    /// decoder for lease states carried over federation gossip.
    pub fn parse(s: &str) -> Option<LeaseState> {
        match s {
            "alive" => Some(LeaseState::Alive),
            "suspect" => Some(LeaseState::Suspect),
            "dead" => Some(LeaseState::Dead),
            "recovering" => Some(LeaseState::Recovering),
            _ => None,
        }
    }

    /// Pessimism rank for merging two opinions about the same resource:
    /// `Alive < Suspect < Recovering < Dead`. A merged fleet view takes the
    /// higher rank, except that only the owning coordinator's opinion may
    /// push a resource to `Dead` fleet-wide (see `coordinator::federation`).
    pub fn severity(&self) -> u8 {
        match self {
            LeaseState::Alive => 0,
            LeaseState::Suspect => 1,
            LeaseState::Recovering => 2,
            LeaseState::Dead => 3,
        }
    }
}

/// One resource's lease: state plus the counters that drive transitions.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceLease {
    pub state: LeaseState,
    /// Consecutive missed sweeps (0 when Alive/Recovering).
    pub misses: u32,
    /// Consecutive clean sweeps while Recovering (0 otherwise).
    pub clean_sweeps: u32,
    /// Clock time the current state was entered.
    pub since: f64,
    /// Clock time of the last successful scrape (`None` if never).
    pub last_seen: Option<f64>,
}

impl ResourceLease {
    /// A fresh lease for a resource first seen alive at `now`.
    pub fn alive(now: f64) -> ResourceLease {
        ResourceLease {
            state: LeaseState::Alive,
            misses: 0,
            clean_sweeps: 0,
            since: now,
            last_seen: Some(now),
        }
    }
}

/// A state transition with coordinator-visible side effects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    /// The resource crossed into Dead this sweep: drain it, exclude it
    /// from candidates, relocate its functions.
    Died,
    /// The resource completed quarantine and is Alive again: restore its
    /// candidate memberships.
    Readmitted,
}

/// Advance one resource's lease by one sweep. `ok` is whether this sweep's
/// scrape succeeded; `prev` is the lease from the previous snapshot (`None`
/// for a resource never swept before). Returns the new lease and the
/// transition the coordinator must act on, if any.
pub fn step(
    cfg: &LivenessConfig,
    prev: Option<&ResourceLease>,
    ok: bool,
    now: f64,
) -> (ResourceLease, Option<Transition>) {
    let dead_after = cfg.dead_after.max(1);
    let quarantine = cfg.quarantine_sweeps.max(1);
    let Some(prev) = prev else {
        // First sweep ever for this resource.
        return if ok {
            (ResourceLease::alive(now), None)
        } else if dead_after <= 1 {
            (
                ResourceLease {
                    state: LeaseState::Dead,
                    misses: 1,
                    clean_sweeps: 0,
                    since: now,
                    last_seen: None,
                },
                Some(Transition::Died),
            )
        } else {
            (
                ResourceLease {
                    state: LeaseState::Suspect,
                    misses: 1,
                    clean_sweeps: 0,
                    since: now,
                    last_seen: None,
                },
                None,
            )
        };
    };
    match (prev.state, ok) {
        (LeaseState::Alive, true) => {
            let mut l = prev.clone();
            l.last_seen = Some(now);
            (l, None)
        }
        (LeaseState::Alive | LeaseState::Suspect, false) => {
            let misses = prev.misses + 1;
            if misses >= dead_after {
                (
                    ResourceLease {
                        state: LeaseState::Dead,
                        misses,
                        clean_sweeps: 0,
                        since: now,
                        last_seen: prev.last_seen,
                    },
                    Some(Transition::Died),
                )
            } else {
                (
                    ResourceLease {
                        state: LeaseState::Suspect,
                        misses,
                        clean_sweeps: 0,
                        since: if prev.state == LeaseState::Suspect { prev.since } else { now },
                        last_seen: prev.last_seen,
                    },
                    None,
                )
            }
        }
        (LeaseState::Suspect, true) => {
            // A Suspect resource was never drained, so a clean sweep
            // restores it directly — no quarantine.
            (ResourceLease::alive(now), None)
        }
        (LeaseState::Dead, false) => {
            let mut l = prev.clone();
            l.misses = prev.misses.saturating_add(1);
            (l, None)
        }
        (LeaseState::Dead, true) => {
            if quarantine <= 1 {
                (ResourceLease::alive(now), Some(Transition::Readmitted))
            } else {
                (
                    ResourceLease {
                        state: LeaseState::Recovering,
                        misses: 0,
                        clean_sweeps: 1,
                        since: now,
                        last_seen: Some(now),
                    },
                    None,
                )
            }
        }
        (LeaseState::Recovering, true) => {
            let clean = prev.clean_sweeps + 1;
            if clean >= quarantine {
                (ResourceLease::alive(now), Some(Transition::Readmitted))
            } else {
                let mut l = prev.clone();
                l.clean_sweeps = clean;
                l.last_seen = Some(now);
                (l, None)
            }
        }
        (LeaseState::Recovering, false) => {
            // Flapped during quarantine: straight back to Dead. It was
            // never re-admitted, so there is nothing to drain again.
            (
                ResourceLease {
                    state: LeaseState::Dead,
                    misses: 1,
                    clean_sweeps: 0,
                    since: now,
                    last_seen: prev.last_seen,
                },
                None,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(dead_after: u32, quarantine: u32) -> LivenessConfig {
        LivenessConfig { dead_after, quarantine_sweeps: quarantine }
    }

    /// Drive a sweep sequence from scratch; returns (final lease, transitions).
    fn drive(c: &LivenessConfig, sweeps: &[bool]) -> (ResourceLease, Vec<Transition>) {
        let mut lease: Option<ResourceLease> = None;
        let mut transitions = Vec::new();
        for (i, &ok) in sweeps.iter().enumerate() {
            let (next, t) = step(c, lease.as_ref(), ok, i as f64);
            transitions.extend(t);
            lease = Some(next);
        }
        (lease.unwrap(), transitions)
    }

    #[test]
    fn alive_suspect_dead_progression() {
        let c = cfg(3, 2);
        let (l, t) = drive(&c, &[true]);
        assert_eq!(l.state, LeaseState::Alive);
        assert!(t.is_empty());
        let (l, t) = drive(&c, &[true, false]);
        assert_eq!((l.state, l.misses), (LeaseState::Suspect, 1));
        assert!(t.is_empty());
        let (l, t) = drive(&c, &[true, false, false]);
        assert_eq!((l.state, l.misses), (LeaseState::Suspect, 2));
        assert!(t.is_empty());
        let (l, t) = drive(&c, &[true, false, false, false]);
        assert_eq!((l.state, l.misses), (LeaseState::Dead, 3));
        assert_eq!(t, vec![Transition::Died]);
        assert!(!l.state.schedulable());
    }

    #[test]
    fn suspect_recovers_without_quarantine() {
        let c = cfg(3, 2);
        let (l, t) = drive(&c, &[true, false, false, true]);
        assert_eq!(l.state, LeaseState::Alive);
        assert_eq!(l.misses, 0);
        assert!(t.is_empty(), "Suspect -> Alive is not a re-admission");
        assert!(l.state.schedulable());
    }

    #[test]
    fn dead_requires_full_quarantine_to_readmit() {
        let c = cfg(2, 3);
        let (l, t) = drive(&c, &[false, false]);
        assert_eq!(l.state, LeaseState::Dead);
        assert_eq!(t, vec![Transition::Died]);
        // One clean sweep: quarantined, still not schedulable.
        let (l, t) = drive(&c, &[false, false, true]);
        assert_eq!((l.state, l.clean_sweeps), (LeaseState::Recovering, 1));
        assert_eq!(t, vec![Transition::Died]);
        assert!(!l.state.schedulable());
        // Three clean sweeps: re-admitted.
        let (l, t) = drive(&c, &[false, false, true, true, true]);
        assert_eq!(l.state, LeaseState::Alive);
        assert_eq!(t, vec![Transition::Died, Transition::Readmitted]);
    }

    #[test]
    fn flap_during_quarantine_goes_back_to_dead_without_second_drain() {
        let c = cfg(2, 2);
        let (l, t) = drive(&c, &[false, false, true, false]);
        assert_eq!(l.state, LeaseState::Dead);
        assert_eq!(t, vec![Transition::Died], "no second Died for a quarantine flap");
        // A full kill -> recover -> kill cycle does fire Died twice.
        let (l, t) = drive(&c, &[false, false, true, true, false, false]);
        assert_eq!(l.state, LeaseState::Dead);
        assert_eq!(
            t,
            vec![Transition::Died, Transition::Readmitted, Transition::Died],
            "a re-admitted resource that dies again is drained again"
        );
        assert_eq!(l.misses, 2);
    }

    #[test]
    fn dead_after_one_marks_dead_immediately() {
        let c = cfg(1, 1);
        let (l, t) = drive(&c, &[false]);
        assert_eq!(l.state, LeaseState::Dead);
        assert_eq!(t, vec![Transition::Died]);
        let (l, t) = drive(&c, &[false, true]);
        assert_eq!(l.state, LeaseState::Alive, "quarantine of 1 re-admits on first clean sweep");
        assert_eq!(t, vec![Transition::Died, Transition::Readmitted]);
    }

    #[test]
    fn parse_inverts_as_str_and_severity_orders_pessimism() {
        for s in [
            LeaseState::Alive,
            LeaseState::Suspect,
            LeaseState::Recovering,
            LeaseState::Dead,
        ] {
            assert_eq!(LeaseState::parse(s.as_str()), Some(s));
        }
        assert_eq!(LeaseState::parse("zombie"), None);
        assert!(LeaseState::Alive.severity() < LeaseState::Suspect.severity());
        assert!(LeaseState::Suspect.severity() < LeaseState::Recovering.severity());
        assert!(LeaseState::Recovering.severity() < LeaseState::Dead.severity());
    }

    #[test]
    fn timestamps_track_state_entry_and_last_success() {
        let c = cfg(3, 2);
        let (l, _) = drive(&c, &[true, true, false, false]);
        assert_eq!(l.since, 2.0, "Suspect entered at the first miss");
        assert_eq!(l.last_seen, Some(1.0));
        let (l, _) = drive(&c, &[true, false, false, false]);
        assert_eq!(l.since, 3.0, "Dead entered at the fatal miss");
        assert_eq!(l.last_seen, Some(0.0));
    }
}
