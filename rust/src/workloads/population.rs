//! Seeded workload populations over a device fleet.
//!
//! A **population** is a set of simulated edge devices, each running one
//! workflow **archetype** with its own arrival-rate model. Everything is
//! derived from a single `u64` seed through [`SplitMix64`] split streams,
//! so the submission schedule is *byte-identical* across runs, machines,
//! and engine shard counts — the property the seed-reproducibility suite
//! and the CI determinism gate assert.
//!
//! The pipeline has three stages, deliberately separable:
//!
//! 1. [`generate`] — pure data: `PopulationSpec -> Vec<Submission>`,
//!    sorted by `(at_ns, device)`. No engine, no clock, no I/O.
//!    [`schedule_digest`] fingerprints it.
//! 2. [`install_population`] — register the archetype apps (one per
//!    `(archetype, cell)`) and their stub handlers on a live coordinator.
//!    Handlers *sleep virtual service time* on the coordinator's clock and
//!    nothing else, so a run's end-to-end latency is queueing + service
//!    under the engine's real dispatch/QoS/batching machinery.
//! 3. [`run_population`] — replay the schedule: pace submissions on the
//!    clock (a [`SimActor`] under [`SimClock`](crate::simnet::SimClock),
//!    a plain sleep otherwise), collect every run's outcome as it
//!    completes (an `on_engine_event` subscriber consumes finished runs
//!    immediately, so the engine's bounded finished-run retention can
//!    never evict an unobserved result), and fold per-QoS-class counters
//!    and latency vectors into a [`PopulationReport`].
//!
//! ### Determinism contract
//!
//! Same seed ⇒ identical [`Submission`] bytes (always), and identical
//! per-run firing orders (chain-shaped archetype DAGs keep
//! `WorkflowResult::firing_order` deterministic at any worker/shard
//! count). [`PopulationReport::firing_digest`] folds outcomes in
//! *submission order*, so two same-seed runs with deadlines stripped and
//! backpressure raised ([`RunConfig::determinism`]) produce equal digests
//! at any shard count. Measured (non-determinism) configs keep deadlines
//! and default backpressure: shed/deadline-miss *rates* are then real
//! measurements and may vary run to run — only the schedule stays
//! byte-identical.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cluster::faas::NativeExecutor;
use crate::coordinator::functions::FunctionPackage;
use crate::coordinator::{
    EdgeFaaS, EngineError, EngineEvent, Priority, QoS, ResourceId, RunId, RunStatus, WaitError,
};
use crate::simnet::SimActor;
use crate::util::rng::SplitMix64;

// ---------------------------------------------------------------- archetypes

/// A workflow archetype: a small chain-shaped DAG with fixed per-stage
/// virtual service times and a QoS class. Chains (single dependency per
/// stage; fan-out expressed as entry-instance parallelism) keep firing
/// orders deterministic — the engine guarantees order only for chain DAGs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Archetype {
    /// The paper's video-analytics shape: capture on a device box, analyze
    /// on the cell hub. `Realtime`, tight deadline.
    Video,
    /// Federated learning: parallel on-device training (entry instances on
    /// several boxes), aggregate on the hub. `Batch`, no deadline.
    FedLearn,
    /// Synthetic fan-out/fan-in: a wide scatter across the cell's boxes
    /// reduced by a single gather. `Interactive`, loose deadline.
    FanOut,
}

impl Archetype {
    pub const ALL: [Archetype; 3] = [Archetype::Video, Archetype::FedLearn, Archetype::FanOut];

    /// Stable lowercase name (used in app names; must stay alphanumeric —
    /// the YAML application field and object URLs both embed it).
    pub fn name(self) -> &'static str {
        match self {
            Archetype::Video => "video",
            Archetype::FedLearn => "fl",
            Archetype::FanOut => "fanout",
        }
    }

    /// The chain stages: `(name, nodetype, virtual service seconds)`.
    /// Stage 0 is the entry (data affinity, `reduce: auto`); later stages
    /// reduce to one instance with function affinity.
    pub fn stages(self) -> &'static [(&'static str, &'static str, f64)] {
        match self {
            Archetype::Video => &[("capture", "iot", 0.05), ("analyze", "edge", 0.2)],
            Archetype::FedLearn => &[("train", "iot", 0.5), ("aggregate", "edge", 0.1)],
            Archetype::FanOut => &[("scatter", "iot", 0.02), ("gather", "edge", 0.05)],
        }
    }

    /// How many of a cell's device boxes the entry stage anchors on
    /// (= entry instances per run).
    pub fn anchor_width(self) -> usize {
        match self {
            Archetype::Video => 1,
            Archetype::FedLearn => 4,
            Archetype::FanOut => 8,
        }
    }

    /// The class (and, unless stripped, the relative deadline) every
    /// submission of this archetype carries.
    pub fn qos(self, strip_deadlines: bool) -> QoS {
        let q = match self {
            Archetype::Video => QoS::class(Priority::Realtime).with_deadline(5.0),
            Archetype::FedLearn => QoS::class(Priority::Batch),
            Archetype::FanOut => QoS::class(Priority::Interactive).with_deadline(20.0),
        };
        if strip_deadlines {
            QoS::class(q.priority)
        } else {
            q
        }
    }

    /// QoS-class index (0 Realtime, 1 Interactive, 2 Batch) — the
    /// [`PopulationReport::per_class`] row this archetype lands in.
    pub fn class_index(self) -> usize {
        match self.qos(true).priority {
            Priority::Realtime => 0,
            Priority::Interactive => 1,
            Priority::Batch => 2,
        }
    }
}

// ------------------------------------------------------------- arrival models

/// Per-device arrival process (rates are per device, so aggregate load
/// scales linearly with the device count).
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Memoryless arrivals at `rate_hz` events/sec: exponential
    /// inter-arrival times.
    Poisson { rate_hz: f64 },
    /// On/off bursts: exponentially distributed ON periods (mean
    /// `mean_on_s`) with Poisson arrivals at `rate_hz`, separated by
    /// exponentially distributed OFF periods (mean `mean_off_s`).
    Bursty { rate_hz: f64, mean_on_s: f64, mean_off_s: f64 },
}

/// One archetype's share of the population.
#[derive(Debug, Clone, Copy)]
pub struct ArchetypeLoad {
    pub archetype: Archetype,
    /// Fraction of devices running this archetype (weights are normalized
    /// over the spec's loads).
    pub weight: f64,
    pub arrival: Arrival,
}

/// A fully seeded population description. Pure data: two equal specs
/// always generate byte-identical schedules.
#[derive(Debug, Clone)]
pub struct PopulationSpec {
    pub seed: u64,
    /// Simulated devices (traffic sources). Devices are multiplexed onto
    /// the registered fleet: device `d` lives in cell `d % cells`.
    pub devices: usize,
    /// App cells (each cell gets its own `(archetype, cell)` app anchored
    /// on its own slice of the fleet).
    pub cells: usize,
    /// Virtual length of the arrival window, seconds.
    pub duration_s: f64,
    pub loads: Vec<ArchetypeLoad>,
}

impl PopulationSpec {
    /// The standard mix the benches and tests use: 30% video devices
    /// (Poisson, ~1 run/min), 20% federated-learning devices (bursty), 50%
    /// fan-out devices (Poisson, ~1 run/min).
    pub fn standard(seed: u64, devices: usize, cells: usize, duration_s: f64) -> PopulationSpec {
        PopulationSpec {
            seed,
            devices,
            cells,
            duration_s,
            loads: vec![
                ArchetypeLoad {
                    archetype: Archetype::Video,
                    weight: 0.3,
                    arrival: Arrival::Poisson { rate_hz: 1.0 / 60.0 },
                },
                ArchetypeLoad {
                    archetype: Archetype::FedLearn,
                    weight: 0.2,
                    arrival: Arrival::Bursty {
                        rate_hz: 1.0 / 20.0,
                        mean_on_s: 10.0,
                        mean_off_s: 50.0,
                    },
                },
                ArchetypeLoad {
                    archetype: Archetype::FanOut,
                    weight: 0.5,
                    arrival: Arrival::Poisson { rate_hz: 1.0 / 60.0 },
                },
            ],
        }
    }
}

/// One scheduled workflow submission. Times are integer nanoseconds from
/// the population start so "byte-identical schedule" is exact, not
/// float-comparison-modulo-epsilon.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Submission {
    pub at_ns: u64,
    pub device: u32,
    pub cell: u32,
    pub archetype: Archetype,
}

/// Generate the full submission schedule for a spec. Pure and
/// deterministic: stream derivation order is fixed (assignment stream
/// first, then one stream per device in index order), and the result is
/// sorted by `(at_ns, device)`.
pub fn generate(spec: &PopulationSpec) -> Vec<Submission> {
    assert!(spec.cells > 0, "population needs at least one cell");
    assert!(!spec.loads.is_empty(), "population needs at least one archetype load");
    let total_weight: f64 = spec.loads.iter().map(|l| l.weight).sum();
    assert!(total_weight > 0.0, "archetype weights must sum to > 0");
    let mut root = SplitMix64::seeded(spec.seed);
    let mut assign = root.split(0);
    let horizon_ns = (spec.duration_s * 1e9) as u64;
    let mut subs = Vec::new();
    for device in 0..spec.devices {
        // Archetype assignment by cumulative weight.
        let mut u = assign.next_f64() * total_weight;
        let mut load = spec.loads[spec.loads.len() - 1];
        for l in &spec.loads {
            if u < l.weight {
                load = *l;
                break;
            }
            u -= l.weight;
        }
        let mut rng = root.split(1 + device as u64);
        let cell = (device % spec.cells) as u32;
        let mut push = |t_s: f64| {
            let at_ns = (t_s * 1e9) as u64;
            if at_ns < horizon_ns {
                subs.push(Submission {
                    at_ns,
                    device: device as u32,
                    cell,
                    archetype: load.archetype,
                });
            }
        };
        match load.arrival {
            Arrival::Poisson { rate_hz } => {
                if rate_hz > 0.0 {
                    let mut t = rng.next_exp(rate_hz);
                    while t < spec.duration_s {
                        push(t);
                        t += rng.next_exp(rate_hz);
                    }
                }
            }
            Arrival::Bursty { rate_hz, mean_on_s, mean_off_s } => {
                if rate_hz > 0.0 {
                    // Start in a random phase of the off period so bursts
                    // are not population-synchronized.
                    let mut t = rng.next_f64() * mean_off_s;
                    while t < spec.duration_s {
                        let on_end = t + rng.next_exp(1.0 / mean_on_s.max(1e-9));
                        let mut a = t + rng.next_exp(rate_hz);
                        while a < on_end && a < spec.duration_s {
                            push(a);
                            a += rng.next_exp(rate_hz);
                        }
                        t = on_end + rng.next_exp(1.0 / mean_off_s.max(1e-9));
                    }
                }
            }
        }
    }
    subs.sort_by_key(|s| (s.at_ns, s.device));
    subs
}

/// FNV-1a fingerprint of a schedule's exact bytes (`at_ns`, `device`,
/// `cell`, archetype index).
pub fn schedule_digest(schedule: &[Submission]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for b in bytes {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    };
    for s in schedule {
        eat(&s.at_ns.to_le_bytes());
        eat(&s.device.to_le_bytes());
        eat(&s.cell.to_le_bytes());
        eat(&[s.archetype.class_index() as u8, s.archetype.anchor_width() as u8]);
    }
    h
}

// --------------------------------------------------------------- installation

/// Handle to the installed `(archetype, cell)` app grid.
#[derive(Debug, Clone)]
pub struct PopulationApps {
    pub cells: usize,
}

impl PopulationApps {
    /// The app name of an `(archetype, cell)` pair — alphanumeric only,
    /// like every other app name in the repo.
    pub fn app_name(archetype: Archetype, cell: u32) -> String {
        format!("pop{}{}", archetype.name(), cell)
    }
}

/// Table-2-style YAML for one archetype's chain at one cell.
fn app_yaml(archetype: Archetype, cell: u32) -> String {
    let stages = archetype.stages();
    let mut y = format!(
        "application: {}\nentrypoint: {}\ndag:\n",
        PopulationApps::app_name(archetype, cell),
        stages[0].0
    );
    for (i, (name, nodetype, _)) in stages.iter().enumerate() {
        y.push_str(&format!("  - name: {name}\n"));
        if i > 0 {
            y.push_str(&format!("    dependencies: {}\n", stages[i - 1].0));
        }
        y.push_str(&format!(
            "    affinity:\n      nodetype: {nodetype}\n      affinitytype: {}\n",
            if i == 0 { "data" } else { "function" }
        ));
        y.push_str(&format!("    reduce: {}\n", if i == 0 { "auto" } else { "1" }));
    }
    y
}

/// Register every archetype's stub handlers and configure + deploy one app
/// per `(archetype, cell)`. `cell_boxes[c]` lists cell `c`'s device-hosting
/// resources (the entry stage anchors on the first
/// [`Archetype::anchor_width`] of them — wrapping never duplicates an
/// anchor, it just narrows the fan-out on small cells).
///
/// Handlers sleep their stage's virtual service time on the coordinator's
/// clock and return an empty output list; all observable load is therefore
/// engine queueing + virtual service, not host CPU.
pub fn install_population(
    faas: &Arc<EdgeFaaS>,
    executor: &Arc<NativeExecutor>,
    cell_boxes: &[Vec<ResourceId>],
) -> anyhow::Result<PopulationApps> {
    for archetype in Archetype::ALL {
        for (stage, _, service_s) in archetype.stages() {
            let clock = Arc::clone(faas.clock());
            let s = *service_s;
            executor.register(&format!("img/pop-{}-{stage}", archetype.name()), move |_: &[u8]| {
                clock.sleep(s);
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        for (cell, boxes) in cell_boxes.iter().enumerate() {
            anyhow::ensure!(!boxes.is_empty(), "cell {cell} has no device boxes");
            let cell = cell as u32;
            let anchors: Vec<ResourceId> =
                boxes.iter().copied().take(archetype.anchor_width()).collect();
            let entry = archetype.stages()[0].0;
            let mut data = HashMap::new();
            data.insert(entry.to_string(), anchors);
            faas.configure_application(&app_yaml(archetype, cell), &data)?;
            let packages: HashMap<String, FunctionPackage> = archetype
                .stages()
                .iter()
                .map(|(s, _, _)| {
                    (
                        s.to_string(),
                        FunctionPackage { code: format!("img/pop-{}-{s}", archetype.name()) },
                    )
                })
                .collect();
            faas.deploy_application(&PopulationApps::app_name(archetype, cell), &packages)?;
        }
    }
    Ok(PopulationApps { cells: cell_boxes.len() })
}

/// Which federation member owns `app` (0 when federation is off or the
/// fleet has a single coordinator).
fn owner_index(coordinators: &[Arc<EdgeFaaS>], app: &str) -> usize {
    match coordinators[0].federation() {
        Some(fed) if coordinators.len() > 1 => {
            (fed.owner_of_app(app) as usize).min(coordinators.len() - 1)
        }
        _ => 0,
    }
}

/// [`install_population`] for a federated fleet: handlers are registered
/// once on the shared executor (the backends are shared, so every
/// coordinator's dispatches reach them), but each `(archetype, cell)` app
/// is configured + deployed **only on its owner** — federation partitions
/// application state by the app→owner mapping, and a non-owner reaches the
/// app by forwarding, not by holding its config.
pub fn install_population_federated(
    coordinators: &[Arc<EdgeFaaS>],
    executor: &Arc<NativeExecutor>,
    cell_boxes: &[Vec<ResourceId>],
) -> anyhow::Result<PopulationApps> {
    anyhow::ensure!(!coordinators.is_empty(), "need at least one coordinator");
    for archetype in Archetype::ALL {
        for (stage, _, service_s) in archetype.stages() {
            let clock = Arc::clone(coordinators[0].clock());
            let s = *service_s;
            executor.register(&format!("img/pop-{}-{stage}", archetype.name()), move |_: &[u8]| {
                clock.sleep(s);
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        for (cell, boxes) in cell_boxes.iter().enumerate() {
            anyhow::ensure!(!boxes.is_empty(), "cell {cell} has no device boxes");
            let cell = cell as u32;
            let app = PopulationApps::app_name(archetype, cell);
            let owner = &coordinators[owner_index(coordinators, &app)];
            let anchors: Vec<ResourceId> =
                boxes.iter().copied().take(archetype.anchor_width()).collect();
            let entry = archetype.stages()[0].0;
            let mut data = HashMap::new();
            data.insert(entry.to_string(), anchors);
            owner.configure_application(&app_yaml(archetype, cell), &data)?;
            let packages: HashMap<String, FunctionPackage> = archetype
                .stages()
                .iter()
                .map(|(s, _, _)| {
                    (
                        s.to_string(),
                        FunctionPackage { code: format!("img/pop-{}-{s}", archetype.name()) },
                    )
                })
                .collect();
            owner.deploy_application(&app, &packages)?;
        }
    }
    Ok(PopulationApps { cells: cell_boxes.len() })
}

// ------------------------------------------------------------------- running

/// How to replay a schedule.
pub struct RunConfig {
    /// Pace submissions with this registered actor (SimClock populations).
    /// `None` paces with the coordinator clock's plain `sleep` — correct
    /// under `VirtualClock` (instant) and `RealClock` (real time).
    pub pacer: Option<SimActor>,
    /// Submit every archetype without its deadline (determinism runs:
    /// which runs miss a deadline is timing-dependent).
    pub strip_deadlines: bool,
    /// Refresh the monitoring snapshot (one liveness sweep) every this
    /// many *virtual* seconds along the schedule; 0 disables.
    pub sweep_every_s: f64,
    /// Wall-clock budget for collecting stragglers after the last
    /// submission; runs still unfinished are reported as `hung`.
    pub drain_timeout_s: f64,
}

impl RunConfig {
    /// Measured mode: deadlines live, periodic sweeps.
    pub fn measured(pacer: Option<SimActor>) -> RunConfig {
        RunConfig { pacer, strip_deadlines: false, sweep_every_s: 5.0, drain_timeout_s: 300.0 }
    }

    /// Determinism mode: no deadlines, no sweeps; pair with raised
    /// backpressure bounds (`set_backpressure`) so nothing is shed and the
    /// outcome digest is shard-count- and run-to-run-stable.
    pub fn determinism(pacer: Option<SimActor>) -> RunConfig {
        RunConfig { pacer, strip_deadlines: true, sweep_every_s: 0.0, drain_timeout_s: 300.0 }
    }
}

/// Per-QoS-class outcome counters (row i = class rank i: 0 Realtime,
/// 1 Interactive, 2 Batch).
#[derive(Debug, Clone, Default)]
pub struct ClassReport {
    pub submitted: usize,
    /// Completed successfully; `e2e_s` holds their engine-clock
    /// end-to-end latencies (virtual seconds under a virtual clock).
    pub completed: usize,
    pub e2e_s: Vec<f64>,
    /// Refused at submission (`EngineError::Saturated`).
    pub saturated: usize,
    /// Admitted, then evicted by a higher-priority submission.
    pub shed: usize,
    /// Missed their QoS deadline.
    pub deadline_missed: usize,
    /// Failed typed with a dead resource (liveness drain, no survivor).
    pub resource_dead: usize,
    /// Any other failure.
    pub failed: usize,
}

/// What a replayed population did.
#[derive(Debug, Clone, Default)]
pub struct PopulationReport {
    pub per_class: [ClassReport; 3],
    /// Fingerprint of the schedule that was replayed.
    pub schedule_digest: u64,
    /// Fold (in submission order) of every outcome + firing order.
    pub firing_digest: u64,
    /// Wall seconds spent in the submission phase.
    pub submit_wall_s: f64,
    /// Wall seconds for the whole replay including straggler collection.
    pub wall_s: f64,
    /// Virtual seconds from first pace to last collected completion.
    pub virtual_makespan_s: f64,
    /// Runs whose record disappeared before an outcome was observed
    /// (bounded finished-run retention; 0 in a healthy replay).
    pub lost: usize,
    /// Runs still unfinished when `drain_timeout_s` expired (0 = the
    /// population never hangs).
    pub hung: usize,
}

impl PopulationReport {
    pub fn submitted(&self) -> usize {
        self.per_class.iter().map(|c| c.submitted).sum()
    }

    pub fn completed(&self) -> usize {
        self.per_class.iter().map(|c| c.completed).sum()
    }
}

#[derive(Debug, Clone)]
enum Outcome {
    Pending,
    Done { duration: f64, firing: Vec<String> },
    Saturated,
    Rejected(String),
    Missed,
    Shed,
    Dead,
    Failed(String),
    Lost,
    Hung,
}

fn fold_digest(h: &mut u64, bytes: &[u8]) {
    for b in bytes {
        *h ^= *b as u64;
        *h = h.wrapping_mul(0x1000_0000_01b3);
    }
}

/// Replay `schedule` against a coordinator where [`install_population`]
/// has run. Consumes each run's engine record as it completes (callers
/// must not `wait_workflow` these runs themselves). Returns the folded
/// report; never blocks longer than the schedule + `drain_timeout_s`.
pub fn run_population(
    faas: &Arc<EdgeFaaS>,
    schedule: &[Submission],
    cfg: RunConfig,
) -> PopulationReport {
    run_population_federated(std::slice::from_ref(faas), schedule, cfg)
}

/// Replay `schedule` against a federated fleet ([`run_population`] is the
/// single-coordinator special case). Each submission is routed to its
/// app's **owner** coordinator — the one
/// [`install_population_federated`] deployed the app on — and outcomes
/// fold into one report in submission order, so a healthy federated
/// replay of a schedule digests byte-identically at any member count.
///
/// With `sweep_every_s > 0` and federation enabled on every member, each
/// sweep point runs an owner-scoped monitor sweep on every coordinator
/// followed by a full in-process gossip exchange (every member merges
/// every peer's view) — the wire path does exactly this over HTTP, the
/// in-process form keeps benches free of socket jitter.
pub fn run_population_federated(
    coordinators: &[Arc<EdgeFaaS>],
    schedule: &[Submission],
    cfg: RunConfig,
) -> PopulationReport {
    assert!(!coordinators.is_empty(), "need at least one coordinator");
    let clock = Arc::clone(coordinators[0].clock());
    // Completed runs stream into this queue from per-coordinator
    // engine-event subscribers that consume (`take_run`) each record the
    // moment its `RunCompleted` fires — the engine's finished-run
    // retention is bounded, so deferring collection to the end would lose
    // early runs. Run ids are per-coordinator counters, so entries carry
    // the member index.
    type Collected = Arc<Mutex<Vec<(usize, RunId, RunStatus)>>>;
    let collected: Collected = Arc::new(Mutex::new(Vec::new()));
    for (k, faas) in coordinators.iter().enumerate() {
        let collected = Arc::clone(&collected);
        faas.on_engine_event(move |faas, ev| {
            if let EngineEvent::RunCompleted { run, .. } = ev {
                match faas.take_run(*run) {
                    // A prior population's subscriber (or a racing waiter)
                    // may have consumed it, or it may still be mid-flight
                    // (impossible after RunCompleted, but harmless): only
                    // terminal statuses are collected.
                    None | Some(RunStatus::Running) => {}
                    Some(st) => collected.lock().unwrap().push((k, *run, st)),
                }
            }
        });
    }

    let wall0 = Instant::now();
    let v0 = clock.now();
    let mut outcomes: Vec<Outcome> = vec![Outcome::Pending; schedule.len()];
    let mut run_of: Vec<Option<(usize, RunId)>> = vec![None; schedule.len()];
    let mut index_of: HashMap<(usize, RunId), usize> = HashMap::new();
    let mut next_sweep =
        if cfg.sweep_every_s > 0.0 { Some(v0 + cfg.sweep_every_s) } else { None };

    let pace_to = |target: f64| {
        let now = clock.now();
        if target > now {
            match &cfg.pacer {
                Some(actor) => actor.sleep(target - now),
                None => clock.sleep(target - now),
            }
        }
    };
    let sweep_all = || {
        let feds: Vec<_> = coordinators.iter().filter_map(|c| c.federation()).collect();
        if feds.len() == coordinators.len() && feds.len() > 1 {
            for f in &feds {
                f.sweep_owned();
            }
            for (i, fi) in feds.iter().enumerate() {
                if let Ok(view) = fi.export_view() {
                    for (j, fj) in feds.iter().enumerate() {
                        if i != j {
                            let _ = fj.receive_gossip(&view);
                        }
                    }
                }
            }
        } else {
            for c in coordinators.iter() {
                c.refresh_monitor_snapshot();
            }
        }
    };
    let drain = |outcomes: &mut Vec<Outcome>, index_of: &HashMap<(usize, RunId), usize>| {
        let batch: Vec<(usize, RunId, RunStatus)> =
            std::mem::take(&mut *collected.lock().unwrap());
        for (k, run, st) in batch {
            let Some(&i) = index_of.get(&(k, run)) else { continue };
            if !matches!(outcomes[i], Outcome::Pending) {
                continue;
            }
            outcomes[i] = match st {
                RunStatus::Done(res) => {
                    Outcome::Done { duration: res.duration, firing: res.firing_order }
                }
                RunStatus::DeadlineExceeded => Outcome::Missed,
                RunStatus::Failed(msg) if msg.contains("shed under backpressure") => {
                    Outcome::Shed
                }
                RunStatus::Failed(msg) if msg.contains("ResourceDead") => Outcome::Dead,
                RunStatus::Failed(msg) => Outcome::Failed(msg),
                RunStatus::Running => unreachable!("filtered by the subscriber"),
            };
        }
    };

    // Submission phase: pace the virtual clock along the schedule,
    // submitting each run at its arrival time and sweeping the monitor on
    // its virtual cadence.
    for (i, sub) in schedule.iter().enumerate() {
        let at = v0 + sub.at_ns as f64 / 1e9;
        while let Some(sweep_at) = next_sweep {
            if sweep_at > at {
                break;
            }
            pace_to(sweep_at);
            sweep_all();
            next_sweep = Some(sweep_at + cfg.sweep_every_s);
        }
        pace_to(at);
        let app = PopulationApps::app_name(sub.archetype, sub.cell);
        let k = owner_index(coordinators, &app);
        match coordinators[k].submit_workflow_qos(
            &app,
            &HashMap::new(),
            sub.archetype.qos(cfg.strip_deadlines),
        ) {
            Ok(run) => {
                run_of[i] = Some((k, run));
                index_of.insert((k, run), i);
            }
            Err(EngineError::Saturated { .. }) => outcomes[i] = Outcome::Saturated,
            Err(EngineError::Rejected(msg)) => outcomes[i] = Outcome::Rejected(msg),
        }
        drain(&mut outcomes, &index_of);
    }
    // Let virtual time free-run past the pacer: in-flight service sleeps
    // drain at event speed.
    if let Some(actor) = &cfg.pacer {
        actor.release();
    }
    let submit_wall_s = wall0.elapsed().as_secs_f64();

    // Straggler collection: bounded wall time, short waits so collection
    // keeps pace with completions.
    let drain_deadline = Instant::now() + std::time::Duration::from_secs_f64(cfg.drain_timeout_s);
    loop {
        drain(&mut outcomes, &index_of);
        let next_pending = (0..schedule.len())
            .find(|&i| matches!(outcomes[i], Outcome::Pending) && run_of[i].is_some());
        let Some(i) = next_pending else { break };
        if Instant::now() >= drain_deadline {
            for o in outcomes.iter_mut() {
                if matches!(o, Outcome::Pending) {
                    *o = Outcome::Hung;
                }
            }
            break;
        }
        let (k, run) = run_of[i].expect("filtered above");
        match coordinators[k].wait_workflow(run, 0.25) {
            Ok(res) => {
                outcomes[i] =
                    Outcome::Done { duration: res.duration, firing: res.firing_order }
            }
            Err(WaitError::Timeout { .. }) => {}
            Err(WaitError::DeadlineExceeded { .. }) => outcomes[i] = Outcome::Missed,
            Err(WaitError::ResourceDead { .. }) => outcomes[i] = Outcome::Dead,
            Err(WaitError::RunFailed { message, .. }) => {
                outcomes[i] = if message.contains("shed under backpressure") {
                    Outcome::Shed
                } else {
                    Outcome::Failed(message)
                };
            }
            // The subscriber consumed it between our drain and this wait
            // (next drain records it) — or it was evicted unobserved.
            Err(WaitError::UnknownRun { .. }) => {
                drain(&mut outcomes, &index_of);
                if matches!(outcomes[i], Outcome::Pending) {
                    outcomes[i] = Outcome::Lost;
                }
            }
        }
    }
    drain(&mut outcomes, &index_of);

    // Fold the report in submission order.
    let mut report = PopulationReport {
        schedule_digest: schedule_digest(schedule),
        ..PopulationReport::default()
    };
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for (sub, outcome) in schedule.iter().zip(&outcomes) {
        let class = &mut report.per_class[sub.archetype.class_index()];
        class.submitted += 1;
        let tag: u8 = match outcome {
            Outcome::Pending => unreachable!("every outcome is terminal after collection"),
            Outcome::Done { duration, firing } => {
                class.completed += 1;
                class.e2e_s.push(*duration);
                for f in firing {
                    fold_digest(&mut h, f.as_bytes());
                }
                1
            }
            Outcome::Saturated => {
                class.saturated += 1;
                2
            }
            Outcome::Rejected(_) | Outcome::Failed(_) => {
                class.failed += 1;
                3
            }
            Outcome::Missed => {
                class.deadline_missed += 1;
                4
            }
            Outcome::Shed => {
                class.shed += 1;
                5
            }
            Outcome::Dead => {
                class.resource_dead += 1;
                6
            }
            Outcome::Lost => {
                report.lost += 1;
                7
            }
            Outcome::Hung => {
                report.hung += 1;
                8
            }
        };
        fold_digest(&mut h, &[tag]);
    }
    report.firing_digest = h;
    report.submit_wall_s = submit_wall_s;
    report.wall_s = wall0.elapsed().as_secs_f64();
    report.virtual_makespan_s = clock.now() - v0;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic_and_seed_sensitive() {
        let spec = PopulationSpec::standard(42, 500, 4, 120.0);
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a, b, "same spec must generate byte-identical schedules");
        assert_eq!(schedule_digest(&a), schedule_digest(&b));
        let other = generate(&PopulationSpec::standard(43, 500, 4, 120.0));
        assert_ne!(
            schedule_digest(&a),
            schedule_digest(&other),
            "different seeds must diverge"
        );
        assert!(!a.is_empty(), "the standard mix produces load");
    }

    #[test]
    fn schedule_is_sorted_in_horizon_and_cell_mapped() {
        let spec = PopulationSpec::standard(7, 300, 5, 60.0);
        let subs = generate(&spec);
        let horizon = (spec.duration_s * 1e9) as u64;
        for w in subs.windows(2) {
            assert!((w[0].at_ns, w[0].device) <= (w[1].at_ns, w[1].device), "sorted");
        }
        for s in &subs {
            assert!(s.at_ns < horizon);
            assert!((s.device as usize) < spec.devices);
            assert_eq!(s.cell, s.device % spec.cells as u32);
        }
    }

    #[test]
    fn load_scales_linearly_with_devices() {
        let small = generate(&PopulationSpec::standard(11, 200, 4, 60.0)).len();
        let large = generate(&PopulationSpec::standard(11, 2000, 4, 60.0)).len();
        let ratio = large as f64 / small.max(1) as f64;
        assert!(
            (5.0..20.0).contains(&ratio),
            "10x devices ≈ 10x submissions, got ratio {ratio}"
        );
    }

    #[test]
    fn archetype_yaml_parses_and_stays_chain_shaped() {
        for archetype in Archetype::ALL {
            let yaml = app_yaml(archetype, 3);
            let parsed = crate::util::yaml::parse(&yaml).expect("yaml parses");
            let cfg = crate::coordinator::AppConfig::from_yaml(&parsed).expect("valid app");
            assert_eq!(cfg.application, PopulationApps::app_name(archetype, 3));
            // Chain: every non-entry stage depends on exactly the previous.
            let stages = archetype.stages();
            for (i, (name, _, _)) in stages.iter().enumerate().skip(1) {
                let f = cfg.function(name).expect("stage present");
                assert_eq!(f.dependencies, vec![stages[i - 1].0.to_string()]);
            }
        }
    }
}
