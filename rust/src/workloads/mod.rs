//! Synthetic workload populations — the scale harness's load generator.
//!
//! Where [`crate::workflows`] carries the *paper's* two workflows (video
//! analytics, federated learning) with their real compute, this module
//! carries seeded *populations* of lightweight workflow archetypes for
//! driving the engine/scheduler/liveness planes at 1k–100k simulated
//! devices: [`population`] turns a `u64` seed into a byte-identical
//! submission schedule (per-archetype Poisson/bursty arrival models over
//! a device population) and replays it against a live coordinator under
//! any [`crate::simnet::Clock`] — the discrete-event
//! [`crate::simnet::SimClock`] for bounded-wall-time runs.
//!
//! See `benches/scale_population.rs` (emits `BENCH_scale.json`) and the
//! README's "Scale harness" section for how the pieces fit.

pub mod population;

pub use population::{
    generate, install_population, install_population_federated, run_population,
    run_population_federated, schedule_digest, Archetype, ArchetypeLoad, Arrival, ClassReport,
    PopulationApps, PopulationReport, PopulationSpec, RunConfig, Submission,
};
