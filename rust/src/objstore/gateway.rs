//! REST gateway for the object store (the MinIO endpoint stand-in).
//!
//! "EdgeFaaS uses HTTP to request the RESTful APIs provided by the FaaS
//! framework and object store" (§3.1). Verbs:
//!
//! ```text
//! PUT    /bucket/{bucket}                 MakeBucket
//! DELETE /bucket/{bucket}                 RemoveBucket
//! GET    /buckets                         ListBuckets
//! PUT    /object/{bucket}/{object...}     FPutObject (body = data)
//! GET    /object/{bucket}/{object...}     FGetObject
//! DELETE /object/{bucket}/{object...}     RemoveObject
//! GET    /objects/{bucket}                ListObjects
//! ```
//!
//! Requests carry the MinIO access/secret keys in headers — the paper's
//! "the user should at least have the read and write privileges enabled".

use std::sync::Arc;

use crate::util::http::{Handler, Request, Response, Server};
use crate::util::json::Json;

use super::store::{ObjectStore, StoreError};

pub struct StoreGateway {
    store: Arc<ObjectStore>,
}

impl StoreGateway {
    pub fn new(store: Arc<ObjectStore>) -> Self {
        StoreGateway { store }
    }

    pub fn serve(store: Arc<ObjectStore>, workers: usize) -> anyhow::Result<Server> {
        let gw = Arc::new(StoreGateway::new(store));
        Server::bind(0, workers, gw as Arc<dyn Handler>)
    }

    fn authorized(&self, req: &Request) -> bool {
        req.headers.get("x-access-key").map(String::as_str) == Some(&self.store.access_key)
            && req.headers.get("x-secret-key").map(String::as_str) == Some(&self.store.secret_key)
    }
}

fn status_of(e: &StoreError) -> u16 {
    match e {
        StoreError::BadBucketName(_) => 400,
        StoreError::BucketExists(_) | StoreError::BucketNotEmpty(_) => 409,
        StoreError::NoBucket(_) | StoreError::NoObject(_) => 404,
        StoreError::Full { .. } => 507,
    }
}

impl Handler for StoreGateway {
    fn handle(&self, req: Request) -> Response {
        if !self.authorized(&req) {
            return Response::text(401, "bad credentials");
        }
        let segs = req.segments();
        let result: Result<Response, StoreError> = match (req.method.as_str(), segs.as_slice()) {
            ("PUT", ["bucket", bucket]) => {
                self.store.make_bucket(bucket).map(|()| Response::text(201, "created"))
            }
            ("DELETE", ["bucket", bucket]) => {
                self.store.remove_bucket(bucket).map(|()| Response::text(200, "removed"))
            }
            ("GET", ["buckets"]) => Ok(Response::json(200, &Json::from(self.store.list_buckets()))),
            ("PUT", ["object", bucket, rest @ ..]) if !rest.is_empty() => {
                let object = rest.join("/");
                // The request body is already a shared window into the
                // connection's read buffer; storing it is a refcount bump.
                self.store
                    .put_object(bucket, &object, req.body.clone())
                    .map(|()| Response::text(201, "stored"))
            }
            ("GET", ["object", bucket, rest @ ..]) if !rest.is_empty() => {
                let object = rest.join("/");
                // Zero-copy: the stored buffer itself becomes the response
                // body (one vectored write at the socket).
                self.store.get_object(bucket, &object).map(|data| Response::bytes(200, data))
            }
            ("DELETE", ["object", bucket, rest @ ..]) if !rest.is_empty() => {
                let object = rest.join("/");
                self.store.remove_object(bucket, &object).map(|()| Response::text(200, "removed"))
            }
            ("GET", ["objects", bucket]) => {
                self.store.list_objects(bucket).map(|names| Response::json(200, &Json::from(names)))
            }
            ("GET", ["healthz"]) => Ok(Response::text(200, "ok")),
            _ => Ok(Response::not_found()),
        };
        result.unwrap_or_else(|e| Response::text(status_of(&e), e.to_string()))
    }
}

/// Client helpers (used by the coordinator's storage virtualization).
/// Every verb has a `_with` variant taking an explicit
/// [`RequestOptions`](crate::util::http::RequestOptions) budget; the plain
/// form runs under the client defaults.
pub mod client {
    use crate::util::http::{self, RequestOptions};

    fn auth<'a>(ak: &'a str, sk: &'a str) -> [(&'a str, &'a str); 2] {
        [("X-Access-Key", ak), ("X-Secret-Key", sk)]
    }

    pub fn make_bucket(addr: &str, ak: &str, sk: &str, bucket: &str) -> anyhow::Result<()> {
        make_bucket_with(addr, ak, sk, bucket, RequestOptions::default())
    }

    /// [`make_bucket`] under an explicit request budget.
    pub fn make_bucket_with(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        opts: RequestOptions,
    ) -> anyhow::Result<()> {
        let resp = http::request_with(
            addr,
            "PUT",
            &format!("/bucket/{bucket}"),
            &auth(ak, sk),
            &[],
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!("make_bucket {bucket}: {} {}", resp.status, resp.body_str().unwrap_or(""));
        }
        Ok(())
    }

    pub fn remove_bucket(addr: &str, ak: &str, sk: &str, bucket: &str) -> anyhow::Result<()> {
        remove_bucket_with(addr, ak, sk, bucket, RequestOptions::default())
    }

    /// [`remove_bucket`] under an explicit request budget.
    pub fn remove_bucket_with(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        opts: RequestOptions,
    ) -> anyhow::Result<()> {
        let resp = http::request_with(
            addr,
            "DELETE",
            &format!("/bucket/{bucket}"),
            &auth(ak, sk),
            &[],
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!("remove_bucket {bucket}: {} {}", resp.status, resp.body_str().unwrap_or(""));
        }
        Ok(())
    }

    pub fn put_object(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        object: &str,
        data: &[u8],
    ) -> anyhow::Result<()> {
        put_object_with(addr, ak, sk, bucket, object, data, RequestOptions::default())
    }

    /// [`put_object`] under an explicit request budget.
    #[allow(clippy::too_many_arguments)]
    pub fn put_object_with(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        object: &str,
        data: &[u8],
        opts: RequestOptions,
    ) -> anyhow::Result<()> {
        let resp = http::request_with(
            addr,
            "PUT",
            &format!("/object/{bucket}/{object}"),
            &auth(ak, sk),
            data,
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!("put_object {bucket}/{object}: {}", resp.status);
        }
        Ok(())
    }

    /// Fetch an object; the returned buffer shares the HTTP response
    /// allocation (no copy).
    pub fn get_object(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        object: &str,
    ) -> anyhow::Result<crate::util::bytes::Bytes> {
        get_object_with(addr, ak, sk, bucket, object, RequestOptions::default())
    }

    /// [`get_object`] under an explicit request budget.
    pub fn get_object_with(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        object: &str,
        opts: RequestOptions,
    ) -> anyhow::Result<crate::util::bytes::Bytes> {
        let resp = http::request_with(
            addr,
            "GET",
            &format!("/object/{bucket}/{object}"),
            &auth(ak, sk),
            &[],
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!("get_object {bucket}/{object}: {}", resp.status);
        }
        Ok(resp.body)
    }

    pub fn remove_object(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        object: &str,
    ) -> anyhow::Result<()> {
        remove_object_with(addr, ak, sk, bucket, object, RequestOptions::default())
    }

    /// [`remove_object`] under an explicit request budget.
    pub fn remove_object_with(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        object: &str,
        opts: RequestOptions,
    ) -> anyhow::Result<()> {
        let resp = http::request_with(
            addr,
            "DELETE",
            &format!("/object/{bucket}/{object}"),
            &auth(ak, sk),
            &[],
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!("remove_object {bucket}/{object}: {}", resp.status);
        }
        Ok(())
    }

    pub fn list_objects(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
    ) -> anyhow::Result<Vec<String>> {
        list_objects_with(addr, ak, sk, bucket, RequestOptions::default())
    }

    /// [`list_objects`] under an explicit request budget.
    pub fn list_objects_with(
        addr: &str,
        ak: &str,
        sk: &str,
        bucket: &str,
        opts: RequestOptions,
    ) -> anyhow::Result<Vec<String>> {
        let resp = http::request_with(
            addr,
            "GET",
            &format!("/objects/{bucket}"),
            &auth(ak, sk),
            &[],
            opts,
        )?;
        if !resp.ok() {
            anyhow::bail!("list_objects {bucket}: {}", resp.status);
        }
        Ok(resp
            .json_body()?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_str().map(String::from))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gw() -> (Server, Arc<ObjectStore>) {
        let store = Arc::new(ObjectStore::new(1 << 24, "ak", "sk"));
        let server = StoreGateway::serve(Arc::clone(&store), 4).unwrap();
        (server, store)
    }

    #[test]
    fn rest_object_lifecycle() {
        let (server, _) = gw();
        let addr = server.addr();
        client::make_bucket(&addr, "ak", "sk", "frames").unwrap();
        client::put_object(&addr, "ak", "sk", "frames", "gop/0.zip", b"zipdata").unwrap();
        let data = client::get_object(&addr, "ak", "sk", "frames", "gop/0.zip").unwrap();
        assert_eq!(data, b"zipdata");
        assert_eq!(
            client::list_objects(&addr, "ak", "sk", "frames").unwrap(),
            vec!["gop/0.zip".to_string()]
        );
        client::remove_object(&addr, "ak", "sk", "frames", "gop/0.zip").unwrap();
        client::remove_bucket(&addr, "ak", "sk", "frames").unwrap();
    }

    #[test]
    fn auth_rejected() {
        let (server, _) = gw();
        let addr = server.addr();
        assert!(client::make_bucket(&addr, "ak", "WRONG", "frames").is_err());
        assert!(client::make_bucket(&addr, "WRONG", "sk", "frames").is_err());
    }

    #[test]
    fn missing_object_404() {
        let (server, _) = gw();
        let addr = server.addr();
        client::make_bucket(&addr, "ak", "sk", "data").unwrap();
        assert!(client::get_object(&addr, "ak", "sk", "data", "nope").is_err());
    }

    #[test]
    fn binary_payload_roundtrip() {
        let (server, _) = gw();
        let addr = server.addr();
        client::make_bucket(&addr, "ak", "sk", "bin").unwrap();
        let mut payload = Vec::with_capacity(100_000);
        let mut rng = crate::util::rng::Pcg32::seeded(1);
        for _ in 0..100_000 {
            payload.push(rng.next_u32() as u8);
        }
        client::put_object(&addr, "ak", "sk", "bin", "blob", &payload).unwrap();
        assert_eq!(client::get_object(&addr, "ak", "sk", "bin", "blob").unwrap(), payload);
    }
}
