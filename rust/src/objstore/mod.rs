//! Object storage substrate (the MinIO stand-in).
//!
//! "Each resource provides its local storage as the EdgeFaaS storage. It is
//! using MinIO by default to organize the local storage" (§3.3.1). This
//! module is that per-resource store: [`store`] implements the MinIO verbs
//! EdgeFaaS calls (MakeBucket, RemoveBucket, FPutObject, FGetObject,
//! RemoveObject, ListObjects) with capacity accounting against the
//! resource's disk, and [`gateway`] exposes them over REST with
//! access/secret-key authentication.

pub mod gateway;
pub mod store;

pub use store::ObjectStore;
