//! Bucket/object store with MinIO-shaped verbs.
//!
//! Semantics follow the paper and MinIO:
//! * bucket names must satisfy (a subset of) the S3 naming rules the paper
//!   references in §3.3.1;
//! * concurrent writes to one object are last-writer-wins ("If EdgeFaaS
//!   receives multiple write requests for the same object simultaneously, it
//!   overwrites all but the last object written");
//! * a bucket must be empty before it can be removed;
//! * capacity is bounded by the resource's registered `storage` size.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::bytes::Bytes;

#[derive(Debug, PartialEq)]
pub enum StoreError {
    BadBucketName(String),
    BucketExists(String),
    NoBucket(String),
    BucketNotEmpty(String),
    NoObject(String),
    Full { need: u64, free: u64 },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::BadBucketName(n) => write!(f, "invalid bucket name `{n}`"),
            StoreError::BucketExists(n) => write!(f, "bucket `{n}` already exists"),
            StoreError::NoBucket(n) => write!(f, "bucket `{n}` not found"),
            StoreError::BucketNotEmpty(n) => write!(f, "bucket `{n}` is not empty"),
            StoreError::NoObject(n) => write!(f, "object `{n}` not found"),
            StoreError::Full { need, free } => {
                write!(f, "store full: need {need} bytes, {free} free")
            }
        }
    }
}

impl std::error::Error for StoreError {}

/// Validate an S3-style bucket name (§3.3.1 points at the AWS rules):
/// 3-63 chars, lowercase letters / digits / hyphens, must start and end with
/// a letter or digit.
pub fn valid_bucket_name(name: &str) -> bool {
    let n = name.len();
    if !(3..=63).contains(&n) {
        return false;
    }
    let bytes = name.as_bytes();
    let ok_edge = |b: u8| b.is_ascii_lowercase() || b.is_ascii_digit();
    if !ok_edge(bytes[0]) || !ok_edge(bytes[n - 1]) {
        return false;
    }
    bytes.iter().all(|&b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'-' || b == b'.')
}

#[derive(Debug, Default)]
struct Inner {
    /// Objects are shared [`Bytes`]: `get_object` hands out a refcount bump,
    /// so a reader holding a payload keeps it alive even across an
    /// overwrite (MinIO-like read snapshot semantics).
    buckets: BTreeMap<String, BTreeMap<String, Bytes>>,
    used: u64,
}

/// A thread-safe in-memory object store with a capacity bound.
#[derive(Debug)]
pub struct ObjectStore {
    inner: Mutex<Inner>,
    capacity: u64,
    /// Access credentials checked by the gateway.
    pub access_key: String,
    pub secret_key: String,
}

impl ObjectStore {
    pub fn new(capacity: u64, access_key: &str, secret_key: &str) -> Self {
        ObjectStore {
            inner: Mutex::new(Inner::default()),
            capacity,
            access_key: access_key.to_string(),
            secret_key: secret_key.to_string(),
        }
    }

    /// MinIO MakeBucket.
    pub fn make_bucket(&self, name: &str) -> Result<(), StoreError> {
        if !valid_bucket_name(name) {
            return Err(StoreError::BadBucketName(name.to_string()));
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.buckets.contains_key(name) {
            return Err(StoreError::BucketExists(name.to_string()));
        }
        inner.buckets.insert(name.to_string(), BTreeMap::new());
        Ok(())
    }

    /// MinIO RemoveBucket — "All objects in the bucket must be deleted before
    /// the bucket itself can be deleted."
    pub fn remove_bucket(&self, name: &str) -> Result<(), StoreError> {
        let mut inner = self.inner.lock().unwrap();
        match inner.buckets.get(name) {
            None => Err(StoreError::NoBucket(name.to_string())),
            Some(objs) if !objs.is_empty() => Err(StoreError::BucketNotEmpty(name.to_string())),
            Some(_) => {
                inner.buckets.remove(name);
                Ok(())
            }
        }
    }

    /// MinIO FPutObject (last-writer-wins on overwrite). Takes shared
    /// [`Bytes`] so the hot path stores a refcount bump, not a copy.
    pub fn put_object(&self, bucket: &str, object: &str, data: Bytes) -> Result<(), StoreError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.buckets.contains_key(bucket) {
            return Err(StoreError::NoBucket(bucket.to_string()));
        }
        let old = inner
            .buckets
            .get(bucket)
            .and_then(|b| b.get(object))
            .map(|v| v.len() as u64)
            .unwrap_or(0);
        let new_used = inner.used - old + data.len() as u64;
        if new_used > self.capacity {
            return Err(StoreError::Full {
                need: data.len() as u64,
                free: self.capacity - (inner.used - old),
            });
        }
        inner.used = new_used;
        inner.buckets.get_mut(bucket).unwrap().insert(object.to_string(), data);
        Ok(())
    }

    /// MinIO FGetObject. Returns shared [`Bytes`] — a refcount bump, not a
    /// copy of the payload.
    pub fn get_object(&self, bucket: &str, object: &str) -> Result<Bytes, StoreError> {
        let inner = self.inner.lock().unwrap();
        inner
            .buckets
            .get(bucket)
            .ok_or_else(|| StoreError::NoBucket(bucket.to_string()))?
            .get(object)
            .cloned()
            .ok_or_else(|| StoreError::NoObject(format!("{bucket}/{object}")))
    }

    /// Object size without copying the payload.
    pub fn stat_object(&self, bucket: &str, object: &str) -> Result<u64, StoreError> {
        let inner = self.inner.lock().unwrap();
        inner
            .buckets
            .get(bucket)
            .ok_or_else(|| StoreError::NoBucket(bucket.to_string()))?
            .get(object)
            .map(|v| v.len() as u64)
            .ok_or_else(|| StoreError::NoObject(format!("{bucket}/{object}")))
    }

    /// MinIO RemoveObject.
    pub fn remove_object(&self, bucket: &str, object: &str) -> Result<(), StoreError> {
        let mut inner = self.inner.lock().unwrap();
        let objs = inner
            .buckets
            .get_mut(bucket)
            .ok_or_else(|| StoreError::NoBucket(bucket.to_string()))?;
        match objs.remove(object) {
            Some(data) => {
                inner.used -= data.len() as u64;
                Ok(())
            }
            None => Err(StoreError::NoObject(format!("{bucket}/{object}"))),
        }
    }

    /// MinIO ListObjects (recursive; sorted).
    pub fn list_objects(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        let inner = self.inner.lock().unwrap();
        Ok(inner
            .buckets
            .get(bucket)
            .ok_or_else(|| StoreError::NoBucket(bucket.to_string()))?
            .keys()
            .cloned()
            .collect())
    }

    /// List bucket names (sorted).
    pub fn list_buckets(&self) -> Vec<String> {
        self.inner.lock().unwrap().buckets.keys().cloned().collect()
    }

    /// Bytes currently stored.
    pub fn used(&self) -> u64 {
        self.inner.lock().unwrap().used
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ObjectStore {
        ObjectStore::new(1 << 20, "ak", "sk")
    }

    #[test]
    fn bucket_name_rules() {
        assert!(valid_bucket_name("videopipeline-frames"));
        assert!(valid_bucket_name("abc"));
        assert!(valid_bucket_name("a.b-c1"));
        assert!(!valid_bucket_name("ab"));
        assert!(!valid_bucket_name("Uppercase"));
        assert!(!valid_bucket_name("-leading"));
        assert!(!valid_bucket_name("trailing-"));
        assert!(!valid_bucket_name(&"x".repeat(64)));
        assert!(!valid_bucket_name("under_score"));
    }

    #[test]
    fn object_crud_cycle() {
        let s = store();
        s.make_bucket("data").unwrap();
        s.put_object("data", "a.bin", vec![1, 2, 3].into()).unwrap();
        assert_eq!(s.get_object("data", "a.bin").unwrap(), vec![1, 2, 3]);
        assert_eq!(s.stat_object("data", "a.bin").unwrap(), 3);
        assert_eq!(s.list_objects("data").unwrap(), vec!["a.bin".to_string()]);
        s.remove_object("data", "a.bin").unwrap();
        assert_eq!(s.get_object("data", "a.bin"), Err(StoreError::NoObject("data/a.bin".into())));
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn overwrite_is_last_writer_wins() {
        let s = store();
        s.make_bucket("data").unwrap();
        s.put_object("data", "o", vec![0; 100].into()).unwrap();
        s.put_object("data", "o", vec![7; 10].into()).unwrap();
        assert_eq!(s.get_object("data", "o").unwrap(), vec![7; 10]);
        assert_eq!(s.used(), 10, "overwrite releases the old bytes");
    }

    #[test]
    fn nonempty_bucket_cannot_be_removed() {
        let s = store();
        s.make_bucket("data").unwrap();
        s.put_object("data", "o", vec![1].into()).unwrap();
        assert_eq!(s.remove_bucket("data"), Err(StoreError::BucketNotEmpty("data".into())));
        s.remove_object("data", "o").unwrap();
        s.remove_bucket("data").unwrap();
        assert!(s.list_buckets().is_empty());
    }

    #[test]
    fn duplicate_and_missing_buckets() {
        let s = store();
        s.make_bucket("data").unwrap();
        assert_eq!(s.make_bucket("data"), Err(StoreError::BucketExists("data".into())));
        assert_eq!(s.put_object("nope", "o", Bytes::new()), Err(StoreError::NoBucket("nope".into())));
        assert_eq!(s.remove_bucket("nope"), Err(StoreError::NoBucket("nope".into())));
    }

    #[test]
    fn capacity_enforced() {
        let s = ObjectStore::new(100, "ak", "sk");
        s.make_bucket("data").unwrap();
        s.put_object("data", "a", vec![0; 60].into()).unwrap();
        assert!(matches!(s.put_object("data", "b", vec![0; 60].into()), Err(StoreError::Full { .. })));
        // Overwriting the existing object with something that fits is fine.
        s.put_object("data", "a", vec![0; 90].into()).unwrap();
        assert_eq!(s.used(), 90);
    }

    #[test]
    fn used_accounting_survives_overwrites() {
        let s = ObjectStore::new(1000, "ak", "sk");
        s.make_bucket("data").unwrap();
        // Grow, shrink, grow again: used() must track the live size exactly.
        s.put_object("data", "o", vec![0; 100].into()).unwrap();
        assert_eq!(s.used(), 100);
        s.put_object("data", "o", vec![0; 700].into()).unwrap();
        assert_eq!(s.used(), 700, "overwrite releases the old 100 bytes");
        s.put_object("data", "o", vec![0; 10].into()).unwrap();
        assert_eq!(s.used(), 10, "shrinking overwrite frees the delta");
        // A rejected overwrite (would exceed capacity even after releasing
        // the old bytes) must leave both the object and used() untouched.
        let err = s.put_object("data", "o", vec![0; 2000].into()).unwrap_err();
        assert!(matches!(err, StoreError::Full { .. }));
        assert_eq!(s.used(), 10);
        assert_eq!(s.stat_object("data", "o").unwrap(), 10);
        // An overwrite that only fits because it replaces the old object.
        s.put_object("data", "big", vec![0; 980].into()).unwrap();
        s.put_object("data", "big", vec![0; 990].into()).unwrap();
        assert_eq!(s.used(), 1000);
        s.remove_object("data", "big").unwrap();
        s.remove_object("data", "o").unwrap();
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn get_object_shares_the_stored_allocation() {
        let s = store();
        s.make_bucket("data").unwrap();
        let payload = Bytes::from(vec![9u8; 256]);
        s.put_object("data", "o", payload.clone()).unwrap();
        let out = s.get_object("data", "o").unwrap();
        // Zero-copy: the returned buffer is the very allocation we stored.
        assert_eq!(out.as_slice().as_ptr(), payload.as_slice().as_ptr());
        // A held read survives an overwrite (snapshot semantics).
        s.put_object("data", "o", vec![1u8; 4].into()).unwrap();
        assert_eq!(out, vec![9u8; 256]);
    }

    #[test]
    fn concurrent_writers_one_wins() {
        use std::sync::Arc;
        let s = Arc::new(store());
        s.make_bucket("data").unwrap();
        let handles: Vec<_> = (0..8u8)
            .map(|i| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    s.put_object("data", "contested", vec![i; 64].into()).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let v = s.get_object("data", "contested").unwrap();
        assert_eq!(v.len(), 64);
        assert!(v.iter().all(|&b| b == v[0]), "no torn write");
        assert_eq!(s.used(), 64);
    }
}
