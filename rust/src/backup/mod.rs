//! Durable mapping backup (the S3 + DynamoDB stand-in).
//!
//! "All the mappings that EdgeFaaS maintains are backed up in DynamoDB with
//! the mapping-name as the key and content as the value. This is to ensure
//! consistency in case of EdgeFaaS failure or crashes" (§3.1.1). [`kv`]
//! provides that durability against the local filesystem: namespaced
//! key→JSON maps persisted as append-only JSONL with compaction, reloadable
//! after a crash.

pub mod kv;

pub use kv::DurableKv;
