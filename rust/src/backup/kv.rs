//! Durable key-value store with namespaces.
//!
//! Model: `namespace` ≈ the paper's mapping name (resource mapping, bucket
//! map, application_bucket mapping, candidate_resource mapping); within a
//! namespace, `key -> Json value`. Writes append a JSONL record
//! (`{"ns":..,"k":..,"v":..}` or a tombstone) and fsync; `open` replays the
//! log; `compact` rewrites it to the live set. This gives the
//! crash-recoverable behaviour the paper gets from DynamoDB/S3: "EdgeFaaS
//! can still get the mappings from DynamoDB and continue scheduling without
//! losing important information."

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::json::{parse, Json};

#[derive(Debug, Default)]
struct Inner {
    data: BTreeMap<String, BTreeMap<String, Json>>,
    file: Option<File>,
    records: u64,
}

/// Durable, thread-safe, namespaced KV store.
pub struct DurableKv {
    path: PathBuf,
    inner: Mutex<Inner>,
}

impl DurableKv {
    /// Open (or create) a store at `path`, replaying any existing log.
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<DurableKv> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut data: BTreeMap<String, BTreeMap<String, Json>> = BTreeMap::new();
        let mut records = 0;
        if path.exists() {
            let reader = BufReader::new(File::open(&path)?);
            for line in reader.lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                records += 1;
                let rec = parse(&line)
                    .map_err(|e| anyhow::anyhow!("corrupt log record {records}: {e}"))?;
                let ns = rec.req_str("ns")?.to_string();
                let k = rec.req_str("k")?.to_string();
                match rec.get("v") {
                    Some(v) => {
                        data.entry(ns).or_default().insert(k, v.clone());
                    }
                    None => {
                        // Tombstone.
                        if let Some(m) = data.get_mut(&ns) {
                            m.remove(&k);
                        }
                    }
                }
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(DurableKv { path, inner: Mutex::new(Inner { data, file: Some(file), records }) })
    }

    /// In-memory store (tests / ephemeral benches): no durability.
    pub fn ephemeral() -> DurableKv {
        DurableKv {
            path: PathBuf::new(),
            inner: Mutex::new(Inner { data: BTreeMap::new(), file: None, records: 0 }),
        }
    }

    fn append(inner: &mut Inner, rec: &Json) -> anyhow::Result<()> {
        if let Some(f) = inner.file.as_mut() {
            writeln!(f, "{rec}")?;
            f.sync_data()?;
        }
        inner.records += 1;
        Ok(())
    }

    /// Put a value.
    pub fn put(&self, ns: &str, key: &str, value: Json) -> anyhow::Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let mut rec = Json::obj();
        rec.set("ns", ns.into()).set("k", key.into()).set("v", value.clone());
        Self::append(&mut inner, &rec)?;
        inner.data.entry(ns.to_string()).or_default().insert(key.to_string(), value);
        Ok(())
    }

    /// Get a value.
    pub fn get(&self, ns: &str, key: &str) -> Option<Json> {
        self.inner.lock().unwrap().data.get(ns).and_then(|m| m.get(key)).cloned()
    }

    /// Delete a key (idempotent).
    pub fn delete(&self, ns: &str, key: &str) -> anyhow::Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let existed = inner.data.get_mut(ns).map(|m| m.remove(key).is_some()).unwrap_or(false);
        if existed {
            let mut rec = Json::obj();
            rec.set("ns", ns.into()).set("k", key.into());
            Self::append(&mut inner, &rec)?;
        }
        Ok(())
    }

    /// All keys in a namespace (sorted).
    pub fn keys(&self, ns: &str) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .data
            .get(ns)
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// All `(key, value)` pairs in a namespace.
    pub fn entries(&self, ns: &str) -> Vec<(String, Json)> {
        self.inner
            .lock()
            .unwrap()
            .data
            .get(ns)
            .map(|m| m.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
            .unwrap_or_default()
    }

    /// Number of log records written since open (compaction trigger).
    pub fn log_records(&self) -> u64 {
        self.inner.lock().unwrap().records
    }

    /// Rewrite the log to contain only live entries.
    pub fn compact(&self) -> anyhow::Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.file.is_none() {
            return Ok(());
        }
        let tmp = self.path.with_extension("tmp");
        {
            let mut f = File::create(&tmp)?;
            for (ns, m) in &inner.data {
                for (k, v) in m {
                    let mut rec = Json::obj();
                    rec.set("ns", ns.as_str().into())
                        .set("k", k.as_str().into())
                        .set("v", v.clone());
                    writeln!(f, "{rec}")?;
                }
            }
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &self.path)?;
        inner.file = Some(OpenOptions::new().append(true).open(&self.path)?);
        inner.records = inner.data.values().map(|m| m.len() as u64).sum();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("edgefaas-kv-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{name}-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn put_get_delete() {
        let kv = DurableKv::ephemeral();
        kv.put("resmap", "0", Json::Str("cloud".into())).unwrap();
        assert_eq!(kv.get("resmap", "0"), Some(Json::Str("cloud".into())));
        kv.delete("resmap", "0").unwrap();
        assert_eq!(kv.get("resmap", "0"), None);
        kv.delete("resmap", "0").unwrap(); // idempotent
    }

    #[test]
    fn namespaces_isolated() {
        let kv = DurableKv::ephemeral();
        kv.put("a", "k", Json::Num(1.0)).unwrap();
        kv.put("b", "k", Json::Num(2.0)).unwrap();
        assert_eq!(kv.get("a", "k"), Some(Json::Num(1.0)));
        assert_eq!(kv.get("b", "k"), Some(Json::Num(2.0)));
        assert_eq!(kv.keys("a"), vec!["k".to_string()]);
    }

    #[test]
    fn survives_reopen() {
        let path = tmpfile("reopen");
        {
            let kv = DurableKv::open(&path).unwrap();
            kv.put("m", "x", Json::Str("1".into())).unwrap();
            kv.put("m", "y", Json::Str("2".into())).unwrap();
            kv.delete("m", "x").unwrap();
            kv.put("m", "z", Json::Str("3".into())).unwrap();
        }
        let kv = DurableKv::open(&path).unwrap();
        assert_eq!(kv.get("m", "x"), None, "tombstone replayed");
        assert_eq!(kv.get("m", "y"), Some(Json::Str("2".into())));
        assert_eq!(kv.keys("m"), vec!["y".to_string(), "z".to_string()]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn compaction_preserves_state_and_shrinks_log() {
        let path = tmpfile("compact");
        let kv = DurableKv::open(&path).unwrap();
        for i in 0..50 {
            kv.put("m", "hot", Json::Num(i as f64)).unwrap();
        }
        assert_eq!(kv.log_records(), 50);
        kv.compact().unwrap();
        assert_eq!(kv.log_records(), 1);
        assert_eq!(kv.get("m", "hot"), Some(Json::Num(49.0)));
        drop(kv);
        let kv = DurableKv::open(&path).unwrap();
        assert_eq!(kv.get("m", "hot"), Some(Json::Num(49.0)));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_corrupt_log() {
        let path = tmpfile("corrupt");
        std::fs::write(&path, "{\"ns\":\"m\",\"k\":\"x\",\"v\":1}\nGARBAGE\n").unwrap();
        assert!(DurableKv::open(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn concurrent_puts() {
        use std::sync::Arc;
        let kv = Arc::new(DurableKv::ephemeral());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let kv = Arc::clone(&kv);
                std::thread::spawn(move || {
                    for j in 0..50 {
                        kv.put("ns", &format!("k{i}-{j}"), Json::Num(j as f64)).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(kv.keys("ns").len(), 400);
    }

    #[test]
    fn complex_values_roundtrip() {
        let path = tmpfile("complex");
        {
            let kv = DurableKv::open(&path).unwrap();
            let mut v = Json::obj();
            v.set("candidates", vec![0u64, 2, 5].into())
                .set("app", "videopipeline".into());
            kv.put("candidate_resource", "videopipeline.face-detection", v).unwrap();
        }
        let kv = DurableKv::open(&path).unwrap();
        let v = kv.get("candidate_resource", "videopipeline.face-detection").unwrap();
        assert_eq!(v.get("candidates").unwrap().as_arr().unwrap().len(), 3);
        let _ = std::fs::remove_file(path);
    }
}
