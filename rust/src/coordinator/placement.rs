//! Data placement (§3.3.2).
//!
//! "EdgeFaaS uses function locality to decide where the data is placed...
//! when data is generated from IoT devices, the data is stored on IoT
//! devices based on data locality. For other intermediate data, if the data
//! volume is large, it is stored where the data is generated to save the
//! data transfer latency."
//!
//! The producing function's resource is therefore the *first choice* for
//! every object it writes; this module provides that decision plus the
//! fallback used when no producer is known (most free storage wins).

use super::resource::{EdgeFaaS, ResourceId};

/// Threshold above which intermediate data is pinned to its producer
/// ("if the data volume is large, it is stored where the data is
/// generated"). Below it, the consumer-side placement is allowed when a
/// consumer hint exists.
pub const LARGE_DATA_BYTES: u64 = 4 << 20;

/// Decide where a producing function's output object should live.
///
/// * large payloads → the producer's resource (save the transfer);
/// * small payloads with a known single consumer → the consumer's resource
///   (ship early, it is cheap);
/// * otherwise → the producer.
pub fn place_output(
    producer: ResourceId,
    consumer: Option<ResourceId>,
    bytes: u64,
) -> ResourceId {
    if bytes >= LARGE_DATA_BYTES {
        return producer;
    }
    consumer.unwrap_or(producer)
}

/// Fallback bucket placement when the caller gives no locality hint: the
/// registered resource with the most free storage (ties to smallest id for
/// determinism).
///
/// NaN-audit note: unlike the scheduler's latency comparisons (now
/// `f64::total_cmp`), this selection is over `u64` byte counts, so the
/// ordering is already total.
pub fn pick_bucket_resource(faas: &EdgeFaaS) -> anyhow::Result<ResourceId> {
    let mut best: Option<(u64, ResourceId)> = None;
    for id in faas.resource_ids() {
        let reg = faas.resource(id)?;
        let capacity = reg.spec.storage * reg.spec.nodes as u64;
        let used = reg.handle.stored_bytes().unwrap_or(0);
        let free = capacity.saturating_sub(used);
        best = match best {
            None => Some((free, id)),
            Some((bf, bi)) => {
                if free > bf || (free == bf && id < bi) {
                    Some((free, id))
                } else {
                    Some((bf, bi))
                }
            }
        };
    }
    best.map(|(_, id)| id).ok_or_else(|| anyhow::anyhow!("no resources registered"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resource::testkit::paper_testbed;
    use crate::simnet::RealClock;
    use std::sync::Arc;

    #[test]
    fn large_outputs_stay_at_producer() {
        assert_eq!(place_output(3, Some(7), 92_000_000), 3, "92 MB video stays put");
        assert_eq!(place_output(3, Some(7), LARGE_DATA_BYTES), 3);
    }

    #[test]
    fn small_outputs_ship_to_consumer() {
        assert_eq!(place_output(3, Some(7), 1024), 7, "single picture ships ahead");
        assert_eq!(place_output(3, None, 1024), 3, "no consumer -> stay");
    }

    #[test]
    fn fallback_prefers_most_free_storage() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        // Cloud has 10 nodes x 512 GB — by far the most storage.
        assert_eq!(pick_bucket_resource(&b.faas).unwrap(), b.cloud);
    }
}
