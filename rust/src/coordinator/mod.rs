//! The EdgeFaaS coordinator — the paper's contribution (§3).
//!
//! EdgeFaaS "provides a unified gateway which could target different
//! platforms using a scheduling mechanism of user's choice... whenever an
//! invocation is made or a deployment requested, EdgeFaaS is in the
//! critical-path and acts like a router, picking some most suitable
//! resources for function execution."
//!
//! Module map (each section of §3 has a module):
//!
//! | paper section                | module        |
//! |------------------------------|---------------|
//! | 3.1 resource management      | [`resource`]  |
//! | 3.1.2 resource monitoring    | [`handle`] (per-resource usage scrape) + [`crate::monitor::snapshot`] (epoch-versioned snapshot plane + collector) |
//! | 3.2.1 function virtualization| [`functions`] |
//! | 3.2.2 DAG creation           | [`appconfig`], [`dag`] |
//! | 3.2.3 function scheduling    | [`scheduler`] (snapshot-backed phases + placement decision cache) |
//! | 3.3.1 storage virtualization | [`storage`]   |
//! | 3.3.2 data placement         | [`placement`] |
//! | execution core               | [`engine`] (event-driven run queue, admission limits) |
//! | sync workflow front-end      | [`invoker`] (`run_workflow` = submit + await) |
//! | async front-end              | [`asyncinvoke`] (`invoke_async` = job + tracker id; auto-reschedule policy) |
//! | unified REST gateway         | [`gateway`]   |
//! | multi-coordinator federation | [`federation`] (epoch-merged gossip, submission forwarding, work stealing) |
//!
//! Every invocation path — synchronous workflow runs, asynchronous function
//! calls, and the REST gateway's `run`/`runs` endpoints — submits through
//! the single [`engine`] core, which owns the QoS-ordered dispatch queues
//! of in-flight workflows (priority class, earliest-deadline-first, aging;
//! see [`engine`]'s module docs), fires DAG nodes as dependency-completion
//! events, enforces per-resource admission limits, and applies
//! backpressure — shedding `Batch`-class work first — once its queue
//! bounds are reached. The engine's hot path is sharded: per-resource
//! dispatch queues and a hash-sharded run table (each shard its own lock +
//! condvar, global invariants in atomics) with targeted wakeups through a
//! small coordination set, so unrelated runs and resources never contend
//! (see [`engine`]'s "Sharding & wakeups"). The engine is clock-generic:
//! the same dispatch code runs under wall-clock time (examples, gateways)
//! and simnet virtual time (figure benches).
//!
//! Placement decisions ride the **monitoring snapshot plane**
//! ([`crate::monitor::snapshot`]): a background collector publishes an
//! epoch-versioned snapshot (per-resource usage with a staleness bound +
//! a dense latency matrix), phase-1 filtering and phase-2 policies read
//! it without a scrape on the decision path (direct-scrape fallback for
//! missing/stale entries), repeated decisions hit a per-epoch cache, and
//! the auto-reschedule policy ([`asyncinvoke`]) watches engine events to
//! migrate hot functions through `reschedule_function`.
//!
//! The coordinator sees resources only through the [`handle::ResourceHandle`]
//! trait, so the same scheduling/placement code runs against in-process
//! backends (virtual-time benches) and loopback-HTTP gateways (examples).

pub mod appconfig;
pub mod asyncinvoke;
pub mod dag;
pub mod engine;
pub mod federation;
pub mod functions;
pub mod gateway;
pub mod handle;
pub mod invoker;
pub mod placement;
pub mod resource;
pub mod scheduler;
pub mod storage;

pub use asyncinvoke::{
    AsyncStatus, AsyncTracker, AutoRescheduleConfig, AutoRescheduler, InvocationId,
};
pub use appconfig::{Affinity, AffinityType, AppConfig, FunctionConfig, Reduce, Requirements};
pub use engine::{
    EngineError, EngineEvent, EngineStats, Priority, QoS, ResourceBusy, RunId, RunStatus,
    StolenInstance, WaitError, ENGINE_SHARDS,
};
pub use federation::{Federation, FederationConfig, PeerSpec};
pub use handle::{LocalHandle, ResourceHandle, VerbBudgets};
pub use invoker::{InstanceResult, WorkflowResult};
pub use resource::{EdgeFaaS, ResourceId};
pub use scheduler::{FunctionCreation, LocalityScheduler, Schedule};
