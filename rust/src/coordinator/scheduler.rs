//! Two-phase function scheduling (§3.2.3).
//!
//! Phase 1 — *filter*: drop resources that violate the privacy requirement
//! (privacy=1 ⇒ only the IoT devices where the input data is generated) or
//! lack free memory/GPUs per the monitoring data.
//!
//! Phase 2 — *placement policy*: the default [`LocalityScheduler`] places by
//! data locality / dependency-function locality with the `reduce: 1|auto`
//! fan-in rule; users can plug any policy through the [`Schedule`] trait
//! ("EdgeFaaS also offers easy to use interface for users to implement
//! their own scheduling policies").
//!
//! # The scheduling fast path
//!
//! Both phases read the **monitoring snapshot plane**
//! ([`crate::monitor::snapshot`]) instead of touching the network:
//!
//! * Phase 1 takes each resource's usage vector from the current
//!   [`crate::monitor::MonitorSnapshot`] when its sample is younger than
//!   the staleness bound (`EdgeFaaS::set_snapshot_max_age`), and falls back
//!   to a direct `handle.usage()` scrape only for missing/stale entries —
//!   with no collector running the snapshot is empty and every decision
//!   degrades to exactly the old per-call-scrape behaviour.
//! * Phase 2's [`ScheduleCtx`] carries the snapshot's dense
//!   [`LatencyMatrix`], so [`ScheduleCtx::closest`] /
//!   [`ScheduleCtx::closest_to_all`] are indexed loads, never per-pair
//!   shortest-path searches.
//!
//! On top of that sits the **placement decision cache** (`SchedCache`):
//! `schedule_function` memoizes its result keyed by
//! `(app, function, data anchors, dependency anchors)` within one snapshot
//! epoch. Memoizing is only sound while decisions are snapshot-backed, so
//! the cache engages only when the current snapshot is non-initial
//! (epoch > 0) and within the staleness bound — with no collector running
//! it is inert and every call pays the full (scraping) path, exactly the
//! pre-snapshot behaviour. The cache is invalidated by epoch bumps (the
//! collector published fresher data), resource (de)registration, app
//! reconfiguration and scheduler swaps, and is *bypassed* by
//! `reschedule_function` — an explicit reschedule must always consult
//! current monitoring data.
//! `benches/ablation_concurrency.rs` §6 tracks the schedule-call rates
//! (`BENCH_schedule.json`).

use std::collections::HashMap;
use std::sync::Arc;

use crate::monitor::snapshot::LatencyMatrix;
use crate::simnet::Tier;

use super::appconfig::{AffinityType, FunctionConfig, Reduce};
use super::resource::{EdgeFaaS, RegisteredResource, ResourceId};

/// "FunctionCreation struct is the input which contains the essential
/// information used to create a function" (§3.2.3).
#[derive(Debug, Clone)]
pub struct FunctionCreation {
    pub app: String,
    pub function: FunctionConfig,
    /// Resources where this function's input data resides (data affinity,
    /// e.g. the IoT devices whose cameras feed it).
    pub data_locations: Vec<ResourceId>,
    /// Placements of the dependency functions (function affinity); one entry
    /// per deployed upstream instance, duplicates meaningful.
    pub dep_locations: Vec<ResourceId>,
}

/// What a policy may look at when placing a function.
pub struct ScheduleCtx<'a> {
    /// Phase-1 survivors, with their capability records.
    pub candidates: Vec<Arc<RegisteredResource>>,
    /// Topology positions of the function's upstream anchors (input data for
    /// `affinitytype: data`, dependency placements for `: function`), in
    /// upstream order, duplicates preserved.
    pub upstream_nodes: Vec<usize>,
    /// Dense one-way latency view of the topology, lifted from the
    /// monitoring snapshot — lookups are indexed loads, not path searches.
    pub latencies: &'a LatencyMatrix,
}

impl<'a> ScheduleCtx<'a> {
    /// Candidates restricted to a tier.
    pub fn of_tier(&self, tier: Tier) -> Vec<&Arc<RegisteredResource>> {
        self.candidates.iter().filter(|r| r.spec.tier == tier).collect()
    }

    /// The candidate of `tier` with the lowest latency from `from_node`.
    ///
    /// Each candidate's latency key is computed exactly once into a
    /// `(latency, id)` vector before the selection, and keys are compared
    /// with `f64::total_cmp`: a NaN latency (e.g. a poisoned monitoring
    /// sample) sorts *last* instead of silently comparing `Equal` and
    /// letting `min_by`'s tie-breaking pick an arbitrary resource. Ties
    /// keep the first candidate in iteration order (ascending resource
    /// id), matching the pre-keyed behaviour.
    pub fn closest(&self, from_node: usize, tier: Tier) -> Option<ResourceId> {
        let keyed: Vec<(f64, ResourceId)> = self
            .of_tier(tier)
            .into_iter()
            .map(|r| (self.latencies.latency(from_node, r.net_node), r.id))
            .collect();
        keyed.into_iter().min_by(|a, b| a.0.total_cmp(&b.0)).map(|(_, id)| id)
    }

    /// The candidate of `tier` minimizing summed latency from all nodes
    /// (keys precomputed once per candidate; NaN-safe, see
    /// [`Self::closest`]).
    pub fn closest_to_all(&self, from_nodes: &[usize], tier: Tier) -> Option<ResourceId> {
        let keyed: Vec<(f64, ResourceId)> = self
            .of_tier(tier)
            .into_iter()
            .map(|r| {
                let sum: f64 =
                    from_nodes.iter().map(|&n| self.latencies.latency(n, r.net_node)).sum();
                (sum, r.id)
            })
            .collect();
        keyed.into_iter().min_by(|a, b| a.0.total_cmp(&b.0)).map(|(_, id)| id)
    }
}

/// The placement decision cache (see the module docs). Lives behind a
/// mutex in [`EdgeFaaS`]; entries are valid for one snapshot epoch.
pub(super) struct SchedCache {
    pub(super) enabled: bool,
    /// The snapshot epoch the entries were computed under.
    pub(super) epoch: u64,
    pub(super) map: HashMap<SchedKey, Vec<ResourceId>>,
    pub(super) hits: u64,
    pub(super) misses: u64,
}

/// Cache key: `(app, function, data anchors, dependency anchors)`. The
/// snapshot epoch is held once per cache generation (`SchedCache::epoch`),
/// not per entry: an epoch bump clears the whole map.
type SchedKey = (String, String, Vec<ResourceId>, Vec<ResourceId>);

impl Default for SchedCache {
    fn default() -> Self {
        SchedCache { enabled: true, epoch: 0, map: HashMap::new(), hits: 0, misses: 0 }
    }
}

impl SchedCache {
    /// Adopt `epoch` as the cache's generation *without* dropping entries.
    /// A federation gossip merge that changed no lease state publishes a
    /// new snapshot epoch, but every cached placement's inputs are still
    /// inside the staleness contract the cache already tolerates — so the
    /// entries stay live across merged epochs instead of cold-starting on
    /// every push (see `EdgeFaaS::merge_federated_view`). Never regresses
    /// to an older epoch.
    pub(super) fn rekey(&mut self, epoch: u64) {
        if epoch > self.epoch {
            self.epoch = epoch;
        }
    }
}

/// A phase-2 scheduling policy. "Schedule() is the interface to implement
/// the scheduling policy... The returned array is an array of resource IDs
/// that gets the function created."
pub trait Schedule: Send + Sync {
    fn schedule(
        &self,
        request: &FunctionCreation,
        ctx: &ScheduleCtx<'_>,
    ) -> anyhow::Result<Vec<ResourceId>>;
}

/// The paper's default policy: scheduling based on data locality.
///
/// * `affinitytype: data` — "EdgeFaaS schedules the functions to be created
///   on the closest user-defined resource to the input data".
/// * `affinitytype: function` — "EdgeFaaS deploys the function based on
///   where the dependencies function is deployed".
/// * `reduce: auto` — one instance per upstream location, deduplicated
///   (several upstreams sharing a closest resource share the instance);
/// * `reduce: 1` — a single instance closest to *all* upstream locations.
pub struct LocalityScheduler;

impl Schedule for LocalityScheduler {
    fn schedule(
        &self,
        request: &FunctionCreation,
        ctx: &ScheduleCtx<'_>,
    ) -> anyhow::Result<Vec<ResourceId>> {
        let f = &request.function;
        if ctx.of_tier(f.affinity.nodetype).is_empty() {
            anyhow::bail!(
                "no candidate {} resources for `{}` after phase-1 filtering",
                f.affinity.nodetype.name(),
                f.name
            );
        }
        if ctx.upstream_nodes.is_empty() {
            // No locality anchor (e.g. a source with unknown data homes):
            // any candidate of the tier, deterministic order.
            let mut of_tier: Vec<ResourceId> =
                ctx.of_tier(f.affinity.nodetype).iter().map(|r| r.id).collect();
            of_tier.sort();
            let take = match f.reduce {
                Reduce::One => 1,
                Reduce::Auto => of_tier.len(),
            };
            return Ok(of_tier.into_iter().take(take).collect());
        }
        match f.reduce {
            Reduce::One => {
                let id = ctx
                    .closest_to_all(&ctx.upstream_nodes, f.affinity.nodetype)
                    .ok_or_else(|| anyhow::anyhow!("no placement for `{}`", f.name))?;
                Ok(vec![id])
            }
            Reduce::Auto => {
                // Closest per upstream, deduplicated but order-preserving.
                let mut out: Vec<ResourceId> = Vec::new();
                for &n in &ctx.upstream_nodes {
                    let id = ctx
                        .closest(n, f.affinity.nodetype)
                        .ok_or_else(|| anyhow::anyhow!("no placement for `{}`", f.name))?;
                    if !out.contains(&id) {
                        out.push(id);
                    }
                }
                Ok(out)
            }
        }
    }
}

impl EdgeFaaS {
    /// Phase 1: filter resources by privacy and capacity requirements.
    ///
    /// Capacity reads come from the monitoring snapshot when the
    /// resource's sample is within the staleness bound; missing/stale
    /// entries fall back to a direct scrape of that resource (§3.1.2's
    /// behaviour, one resource at a time instead of all of them).
    pub fn phase1_filter(&self, request: &FunctionCreation) -> Vec<Arc<RegisteredResource>> {
        let snap = self.monitor.snapshot();
        let max_age = self.monitor.max_age();
        let now = self.clock.now();
        self.phase1_filter_on(&snap, now, max_age, request)
    }

    /// [`Self::phase1_filter`] against an explicit snapshot, so one
    /// scheduling decision reads a single consistent monitoring view for
    /// both phases (no second fetch between phase 1 and the latency
    /// matrix).
    fn phase1_filter_on(
        &self,
        snap: &crate::monitor::MonitorSnapshot,
        now: f64,
        max_age: f64,
        request: &FunctionCreation,
    ) -> Vec<Arc<RegisteredResource>> {
        let resources = self.resources.read().unwrap();
        resources
            .values()
            .filter(|r| {
                // Liveness: a resource whose lease the failure detector has
                // marked Dead (or Recovering through quarantine) never
                // receives new placements. Suspect stays schedulable — one
                // missed scrape must not trigger migrations. No lease
                // (snapshot plane not yet swept) means schedulable.
                if let Some(lease) = snap.lease_of(r.id) {
                    if !lease.state.schedulable() {
                        return false;
                    }
                }
                // Privacy: "the function can only be created on the IoT
                // devices where the input data is generated".
                if request.function.requirements.privacy {
                    if r.spec.tier != Tier::Iot {
                        return false;
                    }
                    if !request.data_locations.is_empty()
                        && !request.data_locations.contains(&r.id)
                    {
                        return false;
                    }
                }
                // Capacity: snapshot read when fresh, direct scrape of the
                // monitoring stand-in otherwise.
                let usage = match snap.fresh_usage_of(r.id, now, max_age) {
                    Some(u) => Ok(*u),
                    None => r.handle.usage(),
                };
                match usage {
                    Ok(u) => {
                        let mem_total =
                            if u.mem_total > 0 { u.mem_total } else { r.spec.total_memory() };
                        let mem_free = mem_total.saturating_sub(u.mem_used);
                        if request.function.requirements.memory > mem_free {
                            return false;
                        }
                        let gpus_total =
                            if u.gpus_total > 0 { u.gpus_total } else { r.spec.total_gpus() };
                        let gpus_free = gpus_total.saturating_sub(u.gpus_used);
                        request.function.requirements.gpu <= gpus_free
                    }
                    Err(e) => {
                        log::warn!("scrape of resource {} failed: {e}; filtering out", r.id);
                        false
                    }
                }
            })
            .cloned()
            .collect()
    }

    /// Full two-phase scheduling for one function. Returns the chosen
    /// resource ids and records them in the candidate_resource mapping.
    ///
    /// Consults the placement decision cache: a repeated request within
    /// one snapshot epoch returns the memoized placement without
    /// re-running either phase (see the module docs for the invalidation
    /// rules). `reschedule_function` goes through
    /// [`Self::schedule_function_uncached`] instead.
    pub fn schedule_function(&self, request: &FunctionCreation) -> anyhow::Result<Vec<ResourceId>> {
        self.schedule_function_inner(request, true)
    }

    /// Two-phase scheduling that bypasses the decision cache — every call
    /// re-filters against current monitoring data. The computed placement
    /// is *not* inserted into the cache (the caller is explicitly asking
    /// for a load-sensitive decision).
    pub fn schedule_function_uncached(
        &self,
        request: &FunctionCreation,
    ) -> anyhow::Result<Vec<ResourceId>> {
        self.schedule_function_inner(request, false)
    }

    fn schedule_function_inner(
        &self,
        request: &FunctionCreation,
        use_cache: bool,
    ) -> anyhow::Result<Vec<ResourceId>> {
        // One snapshot fetch per decision: phase 1, the phase-2 latency
        // matrix and the cache epoch all come from this single view.
        let snap = self.monitor.snapshot();
        let now = self.clock.now();
        let max_age = self.monitor.max_age();
        let epoch = snap.epoch;
        // Memoizing is sound only while decisions are snapshot-backed: at
        // epoch 0 (nothing ever collected) or past the staleness bound
        // (collector stopped/stalled) phase 1 scrapes live, and caching a
        // load-dependent decision would pin it load-blind forever. In
        // those regimes the cache is inert and every call behaves exactly
        // like the pre-snapshot per-call-scrape path.
        let cacheable = use_cache && epoch > 0 && now - snap.taken_at <= max_age;
        let key = (
            request.app.clone(),
            request.function.name.clone(),
            request.data_locations.clone(),
            request.dep_locations.clone(),
        );
        if cacheable {
            let mut cache = self.sched_cache.lock().unwrap();
            if cache.enabled {
                if cache.epoch != epoch {
                    cache.map.clear();
                    cache.epoch = epoch;
                }
                if let Some(hit) = cache.map.get(&key) {
                    cache.hits += 1;
                    let chosen = hit.clone();
                    drop(cache);
                    // Hits still (re)record the mapping: callers observe
                    // identical side effects either way.
                    self.set_candidates(&request.app, &request.function.name, chosen.clone())?;
                    return Ok(chosen);
                }
                cache.misses += 1;
            }
        }
        let candidates = self.phase1_filter_on(&snap, now, max_age, request);
        if candidates.is_empty() {
            anyhow::bail!(
                "no resource passes phase-1 filtering for `{}.{}`",
                request.app,
                request.function.name
            );
        }
        // Resolve upstream anchors to topology nodes via the full registry
        // (upstream tiers are usually not candidates themselves).
        let upstream_ids: &[ResourceId] = match request.function.affinity.affinitytype {
            AffinityType::Data => &request.data_locations,
            AffinityType::Function => &request.dep_locations,
        };
        let upstream_nodes: Vec<usize> = {
            let res = self.resources.read().unwrap();
            upstream_ids.iter().filter_map(|id| res.get(id).map(|r| r.net_node)).collect()
        };
        // Borrow the policy through the read guard for the duration of the
        // scheduling call — no clone of the scheduler on the hot path (the
        // guard is released as soon as the decision is made; `set_scheduler`
        // only needs the write lock between decisions). Latencies come from
        // the snapshot's dense matrix — no topology lock, no path searches.
        let chosen = {
            let sched = self.scheduler.read().unwrap();
            let ctx = ScheduleCtx { candidates, upstream_nodes, latencies: snap.latencies() };
            sched.schedule(request, &ctx)?
        };
        if chosen.is_empty() {
            anyhow::bail!("scheduler returned no placement for `{}`", request.function.name);
        }
        if cacheable {
            let mut cache = self.sched_cache.lock().unwrap();
            // Guard against a concurrent epoch bump: an entry computed
            // under an older snapshot must not be filed under the new one.
            if cache.enabled && cache.epoch == epoch {
                cache.map.insert(key, chosen.clone());
            }
        }
        self.set_candidates(&request.app, &request.function.name, chosen.clone())?;
        log::info!(
            "scheduled {}.{} -> resources {:?}",
            request.app,
            request.function.name,
            chosen
        );
        Ok(chosen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::appconfig::{Affinity, Requirements};
    use crate::coordinator::resource::testkit::paper_testbed;
    use crate::simnet::RealClock;

    fn fc(name: &str, tier: Tier, at: AffinityType, reduce: Reduce) -> FunctionConfig {
        FunctionConfig {
            name: name.into(),
            dependencies: vec![],
            requirements: Requirements::default(),
            affinity: Affinity { nodetype: tier, affinitytype: at },
            reduce,
        }
    }

    fn req(function: FunctionConfig, data: Vec<ResourceId>, deps: Vec<ResourceId>) -> FunctionCreation {
        FunctionCreation { app: "t".into(), function, data_locations: data, dep_locations: deps }
    }

    #[test]
    fn data_affinity_auto_colocates_with_each_source() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        let r = req(
            fc("gen", Tier::Iot, AffinityType::Data, Reduce::Auto),
            b.iot.clone(),
            vec![],
        );
        let placed = b.faas.schedule_function(&r).unwrap();
        assert_eq!(placed, b.iot, "one instance per camera, on the camera");
    }

    #[test]
    fn function_affinity_auto_picks_closest_edge_per_set() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        // §5.2: "firstAggregation gets deployed on the two sets of edge
        // servers" — 8 train placements reduce to the 2 closest edges.
        let r = req(
            fc("agg1", Tier::Edge, AffinityType::Function, Reduce::Auto),
            vec![],
            b.iot.clone(),
        );
        let placed = b.faas.schedule_function(&r).unwrap();
        assert_eq!(placed, b.edges);
    }

    #[test]
    fn reduce_one_picks_single_closest_to_all() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        // §5.2: secondAggregation with reduce=1 -> the one cloud resource.
        let r = req(
            fc("agg2", Tier::Cloud, AffinityType::Function, Reduce::One),
            vec![],
            b.edges.clone(),
        );
        let placed = b.faas.schedule_function(&r).unwrap();
        assert_eq!(placed, vec![b.cloud]);
    }

    #[test]
    fn privacy_restricts_to_data_generating_iot_devices() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        let mut f = fc("train", Tier::Iot, AffinityType::Data, Reduce::Auto);
        f.requirements.privacy = true;
        let data = vec![b.iot[0], b.iot[3]];
        let r = req(f, data.clone(), vec![]);
        let survivors = b.faas.phase1_filter(&r);
        let ids: Vec<ResourceId> = survivors.iter().map(|r| r.id).collect();
        assert_eq!(ids, data, "only the devices holding the data survive");
        let placed = b.faas.schedule_function(&r).unwrap();
        assert_eq!(placed, data);
    }

    #[test]
    fn capacity_filter_drops_small_devices() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        // 8 GB per sandbox cannot fit a 4 GB Pi.
        let mut f = fc("big", Tier::Edge, AffinityType::Function, Reduce::Auto);
        f.requirements.memory = 8 << 30;
        let r = req(f, vec![], vec![b.iot[0]]);
        let survivors = b.faas.phase1_filter(&r);
        assert!(survivors.iter().all(|r| r.spec.tier != Tier::Iot));
        // Edges (64 GB) and cloud survive.
        assert!(survivors.iter().any(|r| r.spec.tier == Tier::Edge));
    }

    #[test]
    fn gpu_requirement_only_cloud_survives() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        let mut f = fc("gpu-fn", Tier::Cloud, AffinityType::Function, Reduce::One);
        f.requirements.gpu = 1;
        let r = req(f, vec![], vec![b.edges[0]]);
        let survivors = b.faas.phase1_filter(&r);
        let ids: Vec<ResourceId> = survivors.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![b.cloud]);
    }

    #[test]
    fn unsatisfiable_tier_errors() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        // GPU required but nodetype=edge: phase 1 leaves only cloud, which
        // is not of the requested tier -> scheduling must fail loudly.
        let mut f = fc("bad", Tier::Edge, AffinityType::Function, Reduce::One);
        f.requirements.gpu = 1;
        let r = req(f, vec![], vec![b.edges[0]]);
        assert!(b.faas.schedule_function(&r).is_err());
    }

    #[test]
    fn candidates_recorded_in_mapping_and_kv() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        let r = req(
            fc("gen", Tier::Iot, AffinityType::Data, Reduce::Auto),
            vec![b.iot[0]],
            vec![],
        );
        b.faas.schedule_function(&r).unwrap();
        assert_eq!(b.faas.candidates_of("t", "gen").unwrap(), vec![b.iot[0]]);
        let rec = b.faas.kv.get("candidate_resource", "t.gen").unwrap();
        assert_eq!(rec.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn custom_scheduler_is_honored() {
        struct Pin(ResourceId);
        impl Schedule for Pin {
            fn schedule(
                &self,
                _r: &FunctionCreation,
                _c: &ScheduleCtx<'_>,
            ) -> anyhow::Result<Vec<ResourceId>> {
                Ok(vec![self.0])
            }
        }
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        b.faas.set_scheduler(std::sync::Arc::new(Pin(b.cloud)));
        let r = req(
            fc("gen", Tier::Iot, AffinityType::Data, Reduce::Auto),
            vec![b.iot[0]],
            vec![],
        );
        assert_eq!(b.faas.schedule_function(&r).unwrap(), vec![b.cloud]);
    }

    #[test]
    fn dedup_preserves_upstream_order() {
        let b = paper_testbed(std::sync::Arc::new(RealClock::new()));
        // Upstreams from set 2 first: edge order must follow.
        let deps = vec![b.iot[4], b.iot[5], b.iot[0], b.iot[1]];
        let r = req(fc("agg", Tier::Edge, AffinityType::Function, Reduce::Auto), vec![], deps);
        let placed = b.faas.schedule_function(&r).unwrap();
        assert_eq!(placed, vec![b.edges[1], b.edges[0]]);
    }
}
