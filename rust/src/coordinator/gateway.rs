//! The unified EdgeFaaS REST gateway.
//!
//! "EdgeFaaS provides a unified gateway... It implements the same interfaces
//! as OpenFaaS but allows users to run applications using different
//! resources." Everything a user can do goes through here; resource
//! gateways, data locations and cluster credentials stay hidden (§3.2.1's
//! virtualization argument).
//!
//! ```text
//! POST   /apps                         configure (body: Table-2 YAML; query
//!                                       data_<fn>=<rid,rid> seeds data anchors)
//! GET    /apps/{app}/functions          list_functions
//! GET    /apps/{app}/functions/{fn}     get_function
//! POST   /apps/{app}/functions/{fn}     deploy_function  {code}
//! DELETE /apps/{app}/functions/{fn}     delete_function
//! POST   /apps/{app}/invoke/{fn}        invoke  (JSON body; ?one=true)
//! POST   /apps/{app}/run                run_workflow {entry_inputs}
//!                                       (?async=true -> {run} id, poll below;
//!                                       ?priority=realtime|interactive|batch
//!                                       and ?deadline_s=<f64> set the QoS;
//!                                       a saturated engine answers 429 with
//!                                       a Retry-After header; under
//!                                       federation a non-owner relays to the
//!                                       app's owner — one hop max, QoS query
//!                                       preserved, 502 when the owner is
//!                                       unreachable; async polls go to the
//!                                       coordinator that served the 202)
//! GET    /runs/{id}                     run status incl. QoS class +
//!                                       deadline state; a finished run is
//!                                       returned once, then forgotten
//! PUT    /apps/{app}/buckets/{bucket}   create_bucket (?locality=<rid>)
//! DELETE /apps/{app}/buckets/{bucket}   delete_bucket
//! GET    /apps/{app}/buckets            list_buckets
//! PUT    /apps/{app}/objects/{bucket}/{obj...}   put_object -> {url}
//! GET    /objects?url=...               get_object
//! DELETE /apps/{app}/objects/{bucket}/{obj...}   delete_object
//! GET    /apps/{app}/objects/{bucket}   list_objects
//! GET    /resources                     resource ids
//! GET    /engine/stats                  engine counters: shards, pending
//!                                       runs, queue depth (global + the
//!                                       queue_depths per-shard array the
//!                                       federation steal poll reads), worker
//!                                       pool, dispatch statistics
//! GET    /monitor/snapshot              the monitoring snapshot plane:
//!                                       epoch, staleness bound, per-resource
//!                                       usage samples with ages, scrape
//!                                       failure counts and lease states
//!                                       (?latency=true adds the dense
//!                                       latency matrix)
//! GET    /monitor/liveness              the failure detector: per-resource
//!                                       lease state machine (alive/suspect/
//!                                       dead/recovering), miss counters,
//!                                       detector config, summary counts
//! POST   /federation/gossip             peer snapshot push (epoch-gated
//!                                       merge into the local plane)
//! POST   /federation/steal              export queued instances as loans
//!                                       {thief, max} -> {instances}
//! POST   /federation/complete           thief's outcome report, settles
//!                                       the loan -> {settled}
//! GET    /federation/stats              gossip/forward/steal/loan counters
//!                                       (503 when federation is off)
//! GET    /healthz
//! ```

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::monitor::LeaseState;
use crate::simnet::Clock as _;
use crate::util::http::{self, Handler, HttpError, Request, RequestOptions, Response, Server};
use crate::util::json::Json;

use super::engine::{EngineError, Priority, QoS, RunStatus, WaitError};
use super::federation::Federation;
use super::functions::FunctionPackage;
use super::handle::VerbBudgets;
use super::invoker::WorkflowResult;
use super::resource::EdgeFaaS;
use super::storage::ObjectUrl;

/// HTTP facade over the coordinator.
pub struct EdgeFaasGateway {
    faas: Arc<EdgeFaaS>,
    /// Run ids submitted through `?async=true`. `GET /runs/{id}` serves
    /// only these: engine run ids are a guessable global sequence also used
    /// by synchronous `run_workflow` callers, and a stray poll must not be
    /// able to consume (steal) a sync caller's pending result.
    async_runs: Mutex<HashSet<u64>>,
}

impl EdgeFaasGateway {
    pub fn new(faas: Arc<EdgeFaaS>) -> Self {
        EdgeFaasGateway { faas, async_runs: Mutex::new(HashSet::new()) }
    }

    /// Serve on an ephemeral local port.
    pub fn serve(faas: Arc<EdgeFaaS>, workers: usize) -> anyhow::Result<Server> {
        Server::bind(0, workers, Arc::new(EdgeFaasGateway::new(faas)) as Arc<dyn Handler>)
    }

    fn configure(&self, req: &Request) -> Response {
        let yaml = match req.body_str() {
            Ok(s) => s,
            Err(e) => return Response::bad_request(e.to_string()),
        };
        // Data anchors arrive as query params: data_train=0,1,2
        let mut data_locations: HashMap<String, Vec<u32>> = HashMap::new();
        for (k, v) in &req.query {
            if let Some(fname) = k.strip_prefix("data_") {
                let ids: Vec<u32> = v.split(',').filter_map(|x| x.parse().ok()).collect();
                data_locations.insert(fname.to_string(), ids);
            }
        }
        match self.faas.configure_application(yaml, &data_locations) {
            Ok(plan) => {
                let mut o = Json::obj();
                for (f, ids) in plan {
                    o.set(&f, Json::Arr(ids.into_iter().map(|i| Json::Num(i as f64)).collect()));
                }
                Response::json(201, &o)
            }
            Err(e) => Response::bad_request(e.to_string()),
        }
    }

    fn ok_or_500(r: anyhow::Result<Response>) -> Response {
        r.unwrap_or_else(|e| Response::error(e.to_string()))
    }

    /// Parse the QoS query parameters of `POST /apps/{app}/run`.
    fn qos_from_query(query: &BTreeMap<String, String>) -> anyhow::Result<QoS> {
        let mut qos = QoS::default();
        if let Some(p) = query.get("priority") {
            qos.priority = p.parse()?;
        }
        if let Some(d) = query.get("deadline_s") {
            let secs: f64 =
                d.parse().map_err(|_| anyhow::anyhow!("bad deadline_s `{d}` (want seconds)"))?;
            qos.deadline_s = Some(secs);
        }
        Ok(qos)
    }

    /// Map an admission error: `Saturated` becomes `429 Too Many Requests`
    /// with a `Retry-After` header (whole seconds, rounded up); anything
    /// else stays a 500 like other coordinator errors.
    fn engine_error_response(e: EngineError) -> Response {
        let msg = e.to_string();
        match e {
            EngineError::Saturated { retry_after_s, .. } => {
                let mut r = Response::text(429, msg);
                r.headers.insert(
                    "Retry-After".into(),
                    format!("{}", retry_after_s.ceil().max(1.0) as u64),
                );
                r
            }
            EngineError::Rejected(_) => Response::error(msg),
        }
    }

    /// The `qos` object reported by `GET /runs/{id}`: class, configured
    /// deadline, and the deadline's current state
    /// (`none`/`pending`/`met`/`missed`).
    fn qos_json(qos: QoS, remaining: Option<f64>, state: &str) -> Json {
        let mut o = Json::obj();
        o.set("priority", qos.priority.as_str().into());
        match qos.deadline_s {
            Some(d) => o.set("deadline_s", d.into()),
            None => o.set("deadline_s", Json::Null),
        };
        if let Some(r) = remaining {
            o.set("deadline_remaining_s", r.into());
        }
        o.set("deadline_state", state.into());
        o
    }

    /// JSON shape shared by the sync `run` response and `GET /runs/{id}`.
    fn workflow_result_json(result: &WorkflowResult) -> Json {
        let mut o = Json::obj();
        o.set("duration", result.duration.into());
        o.set(
            "firing_order",
            Json::Arr(result.firing_order.iter().map(|f| Json::Str(f.clone())).collect()),
        );
        let mut fns = Json::obj();
        for (f, instances) in &result.functions {
            let mut arr = Vec::new();
            for i in instances {
                let mut io = Json::obj();
                io.set("resource", (i.resource as u64).into())
                    .set("latency", i.latency.into())
                    .set(
                        "outputs",
                        Json::Arr(i.outputs.iter().map(|u| Json::Str(u.clone())).collect()),
                    );
                arr.push(io);
            }
            fns.set(f, Json::Arr(arr));
        }
        o.set("functions", fns);
        o
    }

    /// Relay `POST /apps/{app}/run` to the app's owner coordinator
    /// (federation submission forwarding). The original query string rides
    /// along — QoS class and deadline budget included — plus a one-hop
    /// marker so a misconfigured fleet can never loop. The relay's own
    /// HTTP budget tracks the submission's deadline when it has one; a
    /// connectivity failure maps to a typed 502 (owner unreachable, with
    /// the `HttpError` chain) rather than a generic 500.
    fn forward_run(&self, req: &Request, app: &str, fed: &Federation, target: &str) -> Response {
        let mut path = format!("/apps/{}/run", http::url_encode(app));
        let mut sep = '?';
        for (k, v) in &req.query {
            if k == "forwarded" {
                continue;
            }
            path.push(sep);
            path.push_str(&http::url_encode(k));
            path.push('=');
            path.push_str(&http::url_encode(v));
            sep = '&';
        }
        path.push(sep);
        path.push_str("forwarded=1");
        let budgets = VerbBudgets::default();
        let deadline = req
            .query
            .get("deadline_s")
            .and_then(|d| d.parse::<f64>().ok())
            .map(|d| Duration::from_secs_f64(d.max(0.0)) + budgets.federation)
            .unwrap_or(budgets.invoke);
        match http::request_with(
            target,
            "POST",
            &path,
            &[("Content-Type", "application/json")],
            &req.body,
            RequestOptions::with_deadline(deadline),
        ) {
            Ok(resp) => {
                fed.note_forward(true);
                let mut out = Response::new(resp.status);
                out.headers.insert("Content-Type".into(), "application/json".into());
                if let Some(ra) = resp.headers.get("Retry-After") {
                    out.headers.insert("Retry-After".into(), ra.clone());
                }
                out.body = resp.body;
                out
            }
            Err(e) => {
                fed.note_forward(false);
                let connectivity =
                    HttpError::of(&e).map(|h| h.is_connectivity()).unwrap_or(false);
                let mut o = Json::obj();
                o.set("error", format!("forward to owner failed: {e:#}").as_str().into())
                    .set("owner", (fed.owner_of_app(app) as u64).into())
                    .set("owner_addr", target.into())
                    .set("connectivity", connectivity.into());
                Response::json(502, &o)
            }
        }
    }
}

impl Handler for EdgeFaasGateway {
    fn handle(&self, req: Request) -> Response {
        let segs: Vec<String> = req.segments().iter().map(|s| s.to_string()).collect();
        let segs_ref: Vec<&str> = segs.iter().map(String::as_str).collect();
        match (req.method.as_str(), segs_ref.as_slice()) {
            ("GET", ["healthz"]) => Response::text(200, "ok"),
            ("GET", ["engine", "stats"]) => {
                let s = self.faas.engine_stats();
                let mut o = Json::obj();
                o.set("shards", (s.shards as u64).into())
                    .set("pending_runs", (s.pending_runs as u64).into())
                    .set("queued_instances", (s.queued_instances as u64).into())
                    .set("workers", (s.workers as u64).into())
                    .set("busy_workers", (s.busy_workers as u64).into())
                    .set("batch_dispatches", s.batch_dispatches.into())
                    .set("instances_dispatched", s.instances_dispatched.into())
                    .set("batching", self.faas.batching_enabled().into())
                    .set("batch_window_s", self.faas.batch_window().into())
                    .set(
                        "queue_depths",
                        Json::Arr(
                            self.faas
                                .shard_queue_depths()
                                .into_iter()
                                .map(|d| (d as u64).into())
                                .collect(),
                        ),
                    );
                Response::json(200, &o)
            }
            ("GET", ["monitor", "snapshot"]) => {
                let snap = self.faas.monitor_snapshot();
                let max_age = self.faas.snapshot_max_age();
                let now = self.faas.clock().now();
                // The hand-rolled serializer prints non-finite floats
                // verbatim (invalid JSON); disconnected latencies are
                // INFINITY, so map non-finite to null.
                let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
                let mut o = Json::obj();
                o.set("epoch", snap.epoch.into())
                    .set("taken_at", num(snap.taken_at))
                    .set("max_age_s", num(max_age))
                    .set("collector_running", self.faas.monitor_collector_running().into())
                    .set("nodes", (snap.latencies().len() as u64).into());
                let mut resources = Json::obj();
                for (rid, s) in snap.samples() {
                    let mut r = Json::obj();
                    r.set("cpu_frac", num(s.usage.cpu_frac))
                        .set("mem_used", s.usage.mem_used.into())
                        .set("mem_total", s.usage.mem_total.into())
                        .set("io_bytes_per_s", num(s.usage.io_bytes_per_s))
                        .set("gpu_frac", num(s.usage.gpu_frac))
                        .set("gpus_used", (s.usage.gpus_used as u64).into())
                        .set("gpus_total", (s.usage.gpus_total as u64).into())
                        .set("collected_at", num(s.collected_at))
                        .set("age_s", num(now - s.collected_at))
                        .set("fresh", (now - s.collected_at <= max_age).into())
                        .set("consecutive_failures", (s.consecutive_failures as u64).into());
                    match &s.last_error {
                        Some(e) => r.set("last_error", e.as_str().into()),
                        None => r.set("last_error", Json::Null),
                    };
                    if let Some(lease) = snap.lease_of(rid) {
                        r.set("lease", lease.state.as_str().into());
                    }
                    resources.set(&rid.to_string(), r);
                }
                o.set("resources", resources);
                if req.query.get("latency").map(|v| v == "true").unwrap_or(false) {
                    let m = snap.latencies();
                    let rows: Vec<Json> = (0..m.len())
                        .map(|from| {
                            Json::Arr((0..m.len()).map(|to| num(m.latency(from, to))).collect())
                        })
                        .collect();
                    o.set("latency_matrix", Json::Arr(rows));
                }
                Response::json(200, &o)
            }
            ("GET", ["monitor", "liveness"]) => {
                let snap = self.faas.monitor_snapshot();
                let cfg = self.faas.liveness_config();
                let now = self.faas.clock().now();
                let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
                let mut o = Json::obj();
                o.set("epoch", snap.epoch.into())
                    .set("dead_after", (cfg.dead_after as u64).into())
                    .set("quarantine_sweeps", (cfg.quarantine_sweeps as u64).into());
                let mut resources = Json::obj();
                let (mut alive, mut suspect, mut dead, mut recovering) = (0u64, 0u64, 0u64, 0u64);
                for (rid, lease) in snap.leases() {
                    match lease.state {
                        LeaseState::Alive => alive += 1,
                        LeaseState::Suspect => suspect += 1,
                        LeaseState::Dead => dead += 1,
                        LeaseState::Recovering => recovering += 1,
                    }
                    let mut r = Json::obj();
                    r.set("state", lease.state.as_str().into())
                        .set("schedulable", lease.state.schedulable().into())
                        .set("misses", (lease.misses as u64).into())
                        .set("clean_sweeps", (lease.clean_sweeps as u64).into())
                        .set("since", num(lease.since))
                        .set("state_age_s", num(now - lease.since))
                        .set("last_seen", num(lease.last_seen));
                    match snap.usage_of(rid).and_then(|s| s.last_error.as_deref()) {
                        Some(e) => r.set("last_error", e.into()),
                        None => r.set("last_error", Json::Null),
                    };
                    resources.set(&rid.to_string(), r);
                }
                o.set("resources", resources);
                let mut summary = Json::obj();
                summary
                    .set("alive", alive.into())
                    .set("suspect", suspect.into())
                    .set("dead", dead.into())
                    .set("recovering", recovering.into());
                o.set("summary", summary);
                Response::json(200, &o)
            }
            ("POST", ["federation", "gossip"]) => match self.faas.federation() {
                None => Response::text(503, "federation not enabled"),
                Some(fed) => Self::ok_or_500((|| {
                    let merged = fed.receive_gossip(&req.json()?)?;
                    let mut o = Json::obj();
                    o.set("merged", merged.is_some().into());
                    if let Some(epoch) = merged {
                        o.set("epoch", epoch.into());
                    }
                    Ok(Response::json(200, &o))
                })()),
            },
            ("POST", ["federation", "steal"]) => match self.faas.federation() {
                None => Response::text(503, "federation not enabled"),
                Some(fed) => Self::ok_or_500((|| {
                    let body = if req.body.is_empty() { Json::obj() } else { req.json()? };
                    let max = body.get("max").and_then(Json::as_u64).unwrap_or(1) as usize;
                    Ok(Response::json(200, &fed.serve_steal(max)?))
                })()),
            },
            ("POST", ["federation", "complete"]) => match self.faas.federation() {
                None => Response::text(503, "federation not enabled"),
                Some(fed) => Self::ok_or_500((|| {
                    let settled = fed.receive_complete(&req.json()?)?;
                    let mut o = Json::obj();
                    o.set("settled", settled.into());
                    Ok(Response::json(200, &o))
                })()),
            },
            ("GET", ["federation", "stats"]) => match self.faas.federation() {
                None => Response::text(503, "federation not enabled"),
                Some(fed) => Response::json(200, &fed.stats_json()),
            },
            ("GET", ["resources"]) => {
                let ids = self.faas.resource_ids();
                Response::json(
                    200,
                    &Json::Arr(ids.into_iter().map(|i| Json::Num(i as f64)).collect()),
                )
            }
            ("POST", ["apps"]) => self.configure(&req),
            ("GET", ["apps", app, "functions"]) => {
                Self::ok_or_500(self.faas.list_functions(app).map(|v| Response::json(200, &v)))
            }
            ("GET", ["apps", app, "functions", f]) => {
                Self::ok_or_500(self.faas.get_function(app, f).map(|v| Response::json(200, &v)))
            }
            ("POST", ["apps", app, "functions", f]) => Self::ok_or_500((|| {
                let body = req.json()?;
                let pkg = FunctionPackage { code: body.req_str("code")?.to_string() };
                self.faas.deploy_function(app, f, &pkg)?;
                Ok(Response::text(201, "deployed"))
            })()),
            ("DELETE", ["apps", app, "functions", f]) => Self::ok_or_500(
                self.faas.delete_function(app, f).map(|()| Response::text(200, "deleted")),
            ),
            ("POST", ["apps", app, "invoke", f]) => Self::ok_or_500((|| {
                let payload = if req.body.is_empty() { Json::obj() } else { req.json()? };
                let one = req.query.get("one").map(|v| v == "true").unwrap_or(false);
                let results = self.faas.invoke(app, f, &payload, one)?;
                let mut arr = Vec::new();
                for (rid, out, lat) in results {
                    let mut o = Json::obj();
                    o.set("resource", (rid as u64).into())
                        .set("latency", lat.into())
                        .set("output", String::from_utf8_lossy(&out).to_string().into());
                    arr.push(o);
                }
                Ok(Response::json(200, &Json::Arr(arr)))
            })()),
            ("POST", ["apps", app, "run"]) => Self::ok_or_500((|| {
                // Federation: submissions land on the app's owner. A relay
                // carries the one-hop marker; a marked request landing on a
                // non-owner is a typed misroute, never a second hop.
                if let Some(fed) = self.faas.federation() {
                    let forwarded =
                        req.query.get("forwarded").map(|v| v == "1").unwrap_or(false);
                    if forwarded && !fed.owns_app(app) {
                        return Ok(Response::text(
                            421,
                            format!(
                                "misrouted forward: app `{app}` is owned by member {}, not {}",
                                fed.owner_of_app(app),
                                fed.config().self_id
                            ),
                        ));
                    }
                    if !forwarded {
                        if let Some(target) = fed.forward_target(app) {
                            let target = target.to_string();
                            return Ok(self.forward_run(&req, app, &fed, &target));
                        }
                    }
                }
                let mut entry_inputs: HashMap<String, Vec<String>> = HashMap::new();
                if !req.body.is_empty() {
                    let body = req.json()?;
                    if let Some(obj) = body.get("entry_inputs").and_then(Json::as_obj) {
                        for (f, urls) in obj {
                            entry_inputs.insert(
                                f.clone(),
                                urls.as_arr()
                                    .unwrap_or(&[])
                                    .iter()
                                    .filter_map(|u| u.as_str().map(String::from))
                                    .collect(),
                            );
                        }
                    }
                }
                let qos = match Self::qos_from_query(&req.query) {
                    Ok(qos) => qos,
                    Err(e) => return Ok(Response::bad_request(e.to_string())),
                };
                // Async submission: hand back the engine run id immediately.
                if req.query.get("async").map(|v| v == "true").unwrap_or(false) {
                    let run = match self.faas.submit_workflow_qos(app, &entry_inputs, qos) {
                        Ok(run) => run,
                        Err(e) => return Ok(Self::engine_error_response(e)),
                    };
                    self.async_runs.lock().unwrap().insert(run);
                    let mut o = Json::obj();
                    o.set("run", run.into());
                    return Ok(Response::json(202, &o));
                }
                let run = match self.faas.submit_workflow_qos(app, &entry_inputs, qos) {
                    Ok(run) => run,
                    Err(e) => return Ok(Self::engine_error_response(e)),
                };
                match self.faas.wait_workflow(run, f64::INFINITY) {
                    Ok(result) => {
                        Ok(Response::json(200, &Self::workflow_result_json(&result)))
                    }
                    // A missed deadline is a client-configured QoS outcome,
                    // not a server fault: report it like `GET /runs/{id}`
                    // does, not as a 500.
                    Err(WaitError::DeadlineExceeded { .. }) => {
                        let mut o = Json::obj();
                        o.set("status", "deadline_exceeded".into()).set("run", run.into());
                        Ok(Response::json(200, &o))
                    }
                    Err(e) => Err(e.into()),
                }
            })()),
            ("GET", ["runs", id]) => Self::ok_or_500((|| {
                let run: u64 = id.parse().map_err(|_| anyhow::anyhow!("bad run id `{id}`"))?;
                // Only runs this gateway submitted asynchronously are
                // pollable (see the `async_runs` field).
                if !self.async_runs.lock().unwrap().contains(&run) {
                    return Ok(Response::not_found());
                }
                // QoS snapshot before take_run consumes the record.
                let qos_info = self.faas.run_qos(run);
                let status = self.faas.take_run(run);
                if !matches!(&status, Some(RunStatus::Running)) {
                    self.async_runs.lock().unwrap().remove(&run);
                }
                let qos_for = |o: &mut Json, state: &str| {
                    if let Some((qos, remaining)) = qos_info {
                        let state = if qos.deadline_s.is_none() && state != "missed" {
                            "none"
                        } else {
                            state
                        };
                        o.set("qos", Self::qos_json(qos, remaining, state));
                    }
                };
                match status {
                    None => Ok(Response::not_found()),
                    Some(RunStatus::Running) => {
                        let mut o = Json::obj();
                        o.set("status", "running".into());
                        qos_for(&mut o, "pending");
                        Ok(Response::json(200, &o))
                    }
                    Some(RunStatus::Failed(msg)) => {
                        let mut o = Json::obj();
                        o.set("status", "failed".into()).set("error", msg.as_str().into());
                        qos_for(&mut o, "met");
                        Ok(Response::json(200, &o))
                    }
                    Some(RunStatus::DeadlineExceeded) => {
                        let mut o = Json::obj();
                        o.set("status", "deadline_exceeded".into());
                        qos_for(&mut o, "missed");
                        Ok(Response::json(200, &o))
                    }
                    Some(RunStatus::Done(result)) => {
                        let mut o = Json::obj();
                        o.set("status", "done".into())
                            .set("result", Self::workflow_result_json(&result));
                        qos_for(&mut o, "met");
                        Ok(Response::json(200, &o))
                    }
                }
            })()),
            ("PUT", ["apps", app, "buckets", bucket]) => Self::ok_or_500((|| {
                let locality = req.query.get("locality").and_then(|v| v.parse().ok());
                self.faas.create_bucket(app, bucket, locality)?;
                Ok(Response::text(201, "created"))
            })()),
            ("DELETE", ["apps", app, "buckets", bucket]) => Self::ok_or_500(
                self.faas.delete_bucket(app, bucket).map(|()| Response::text(200, "deleted")),
            ),
            ("GET", ["apps", app, "buckets"]) => {
                Response::json(200, &Json::from(self.faas.list_buckets(app)))
            }
            ("PUT", ["apps", app, "objects", bucket, rest @ ..]) if !rest.is_empty() => {
                Self::ok_or_500((|| {
                    let object = rest.join("/");
                    // Zero-copy hand-off: the request body (a window into the
                    // connection's read buffer) moves into the store by
                    // refcount when the owning backend is local.
                    let url = self.faas.put_object_bytes(app, bucket, &object, req.body.clone())?;
                    let mut o = Json::obj();
                    o.set("url", url.to_string().as_str().into());
                    Ok(Response::json(201, &o))
                })())
            }
            ("GET", ["objects"]) => Self::ok_or_500((|| {
                let url = req
                    .query
                    .get("url")
                    .ok_or_else(|| anyhow::anyhow!("missing url parameter"))?;
                let data = self.faas.get_object(&ObjectUrl::parse(url)?)?;
                Ok(Response::bytes(200, data))
            })()),
            ("DELETE", ["apps", app, "objects", bucket, rest @ ..]) if !rest.is_empty() => {
                let object = rest.join("/");
                Self::ok_or_500(
                    self.faas
                        .delete_object(app, bucket, &object)
                        .map(|()| Response::text(200, "deleted")),
                )
            }
            ("GET", ["apps", app, "objects", bucket]) => Self::ok_or_500(
                self.faas
                    .list_objects(app, bucket)
                    .map(|names| Response::json(200, &Json::from(names))),
            ),
            _ => Response::not_found(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resource::testkit::paper_testbed;
    use crate::simnet::RealClock;
    use crate::util::http;

    fn served() -> (Server, crate::coordinator::resource::testkit::TestBed) {
        let bed = paper_testbed(Arc::new(RealClock::new()));
        let server = EdgeFaasGateway::serve(Arc::clone(&bed.faas), 4).unwrap();
        (server, bed)
    }

    #[test]
    fn healthz_and_resources() {
        let (server, _bed) = served();
        let addr = server.addr();
        assert_eq!(http::get(&addr, "/healthz").unwrap().status, 200);
        let v = http::get(&addr, "/resources").unwrap().json_body().unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 11);
    }

    #[test]
    fn engine_stats_over_rest() {
        let (server, bed) = served();
        let addr = server.addr();
        let v = http::get(&addr, "/engine/stats").unwrap().json_body().unwrap();
        assert_eq!(
            v.get("shards").unwrap().as_u64().unwrap(),
            bed.faas.engine_shards() as u64
        );
        assert_eq!(v.get("pending_runs").unwrap().as_u64(), Some(0));
        assert_eq!(v.get("batching").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("batch_window_s").unwrap().as_f64(), Some(0.0));
        // Per-shard queue depths (the federation steal poll's overload
        // signal) ride along with the legacy global counters.
        let depths = v.get("queue_depths").unwrap().as_arr().unwrap();
        assert_eq!(depths.len(), bed.faas.engine_shards());
        assert!(depths.iter().all(|d| d.as_u64() == Some(0)));
    }

    #[test]
    fn monitor_snapshot_over_rest() {
        let (server, bed) = served();
        let addr = server.addr();
        // Epoch 0: the plane exists but nothing was ever collected.
        let v = http::get(&addr, "/monitor/snapshot").unwrap().json_body().unwrap();
        assert_eq!(v.get("epoch").unwrap().as_u64(), Some(0));
        assert_eq!(v.get("collector_running").unwrap().as_bool(), Some(false));
        assert!(v.get("resources").unwrap().as_obj().unwrap().is_empty());
        // After a refresh every registered resource has a fresh sample.
        let epoch = bed.faas.refresh_monitor_snapshot();
        assert_eq!(epoch, 1);
        let v = http::get(&addr, "/monitor/snapshot?latency=true")
            .unwrap()
            .json_body()
            .unwrap();
        assert_eq!(v.get("epoch").unwrap().as_u64(), Some(1));
        let resources = v.get("resources").unwrap().as_obj().unwrap();
        assert_eq!(resources.len(), 11);
        for r in resources.values() {
            assert_eq!(r.get("fresh").unwrap().as_bool(), Some(true));
            assert_eq!(r.get("consecutive_failures").unwrap().as_u64(), Some(0));
            assert!(matches!(r.get("last_error"), Some(Json::Null)));
            assert_eq!(r.get("lease").unwrap().as_str(), Some("alive"));
        }
        // ?latency=true adds the dense node matrix (11 topology nodes).
        let matrix = v.get("latency_matrix").unwrap().as_arr().unwrap();
        assert_eq!(matrix.len(), 11);
        assert_eq!(matrix[0].as_arr().unwrap().len(), 11);
    }

    #[test]
    fn liveness_plane_over_rest() {
        let (server, bed) = served();
        let addr = server.addr();
        bed.faas.refresh_monitor_snapshot();
        let v = http::get(&addr, "/monitor/liveness").unwrap().json_body().unwrap();
        assert_eq!(v.get("epoch").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("dead_after").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("quarantine_sweeps").unwrap().as_u64(), Some(2));
        let resources = v.get("resources").unwrap().as_obj().unwrap();
        assert_eq!(resources.len(), 11);
        for r in resources.values() {
            assert_eq!(r.get("state").unwrap().as_str(), Some("alive"));
            assert_eq!(r.get("schedulable").unwrap().as_bool(), Some(true));
            assert_eq!(r.get("misses").unwrap().as_u64(), Some(0));
        }
        let summary = v.get("summary").unwrap();
        assert_eq!(summary.get("alive").unwrap().as_u64(), Some(11));
        assert_eq!(summary.get("dead").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn configure_deploy_invoke_over_rest() {
        let (server, bed) = served();
        let addr = server.addr();
        // Configure the FL app with data anchors on all 8 Pis.
        let anchors: String =
            bed.iot.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(",");
        let resp = http::request(
            &addr,
            "POST",
            &format!("/apps?data_train={anchors}"),
            &[("Content-Type", "application/x-yaml")],
            crate::coordinator::appconfig::federated_learning_yaml().as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 201, "{}", resp.body_str().unwrap_or(""));
        let plan = resp.json_body().unwrap();
        assert_eq!(plan.get("train").unwrap().as_arr().unwrap().len(), 8);
        assert_eq!(plan.get("secondaggregation").unwrap().as_arr().unwrap().len(), 1);

        // Register a handler + deploy one function over REST.
        bed.executor.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        let mut body = Json::obj();
        body.set("code", "img/echo".into());
        let resp =
            http::post_json(&addr, "/apps/federatedlearning/functions/train", &body).unwrap();
        assert_eq!(resp.status, 201, "{}", resp.body_str().unwrap_or(""));

        // Invoke one.
        let resp = http::post_json(
            &addr,
            "/apps/federatedlearning/invoke/train?one=true",
            &Json::obj(),
        )
        .unwrap();
        assert_eq!(resp.status, 200);
        let arr = resp.json_body().unwrap();
        assert_eq!(arr.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn async_run_submits_and_polls_through_the_engine() {
        let (server, bed) = served();
        let addr = server.addr();
        // A single-function app with a slow echo handler.
        bed.executor.register("img/slow-echo", |_: &[u8]| {
            std::thread::sleep(std::time::Duration::from_millis(30));
            Ok(br#"{"outputs":[]}"#.to_vec())
        });
        let yaml = "\
application: asyncdemo
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: edge
      affinitytype: data
    reduce: 1
";
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![bed.iot[0]]);
        bed.faas.configure_application(yaml, &data).unwrap();
        bed.faas
            .deploy_function("asyncdemo", "f", &FunctionPackage { code: "img/slow-echo".into() })
            .unwrap();

        // A malformed priority is refused outright.
        let resp = http::request(
            &addr,
            "POST",
            "/apps/asyncdemo/run?async=true&priority=urgent",
            &[],
            &[],
        )
        .unwrap();
        assert_eq!(resp.status, 400, "{}", resp.body_str().unwrap_or(""));

        let resp = http::request(
            &addr,
            "POST",
            "/apps/asyncdemo/run?async=true&priority=realtime&deadline_s=30",
            &[],
            &[],
        )
        .unwrap();
        assert_eq!(resp.status, 202, "{}", resp.body_str().unwrap_or(""));
        let run = resp.json_body().unwrap().get("run").unwrap().as_u64().unwrap();

        // Poll until done; the finished record is consumed (next GET: 404).
        let mut last = Json::obj();
        for _ in 0..200 {
            let resp = http::get(&addr, &format!("/runs/{run}")).unwrap();
            assert_eq!(resp.status, 200);
            last = resp.json_body().unwrap();
            if last.req_str("status").unwrap() != "running" {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(last.req_str("status").unwrap(), "done");
        // The run's QoS class + deadline state ride along with the status.
        let qos = last.get("qos").expect("qos object reported");
        assert_eq!(qos.req_str("priority").unwrap(), "realtime");
        assert_eq!(qos.req_str("deadline_state").unwrap(), "met");
        assert_eq!(qos.get("deadline_s").unwrap().as_f64().unwrap(), 30.0);
        assert_eq!(http::get(&addr, &format!("/runs/{run}")).unwrap().status, 404);
    }

    #[test]
    fn federation_verbs_over_rest() {
        let (server, bed) = served();
        let addr = server.addr();
        // Federation off: the verbs answer 503, not 404.
        assert_eq!(http::get(&addr, "/federation/stats").unwrap().status, 503);
        let fed = crate::coordinator::federation::Federation::enable(
            &bed.faas,
            crate::coordinator::federation::FederationConfig::new(0, 2),
        )
        .unwrap();
        let v = http::get(&addr, "/federation/stats").unwrap().json_body().unwrap();
        assert_eq!(v.get("self_id").unwrap().as_u64(), Some(0));
        assert_eq!(v.get("members").unwrap().as_u64(), Some(2));
        // A peer's gossip push merges once; the replay is skipped.
        bed.faas.refresh_monitor_snapshot();
        let mut push = Json::obj();
        push.set("from", 1u64.into())
            .set("epoch", 3u64.into())
            .set("owned", Json::Arr(vec![]))
            .set("usage", Json::obj())
            .set("leases", Json::obj());
        let resp = http::post_json(&addr, "/federation/gossip", &push).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.json_body().unwrap().get("merged").unwrap().as_bool(), Some(true));
        let resp = http::post_json(&addr, "/federation/gossip", &push).unwrap();
        assert_eq!(resp.json_body().unwrap().get("merged").unwrap().as_bool(), Some(false));
        // Nothing queued: a steal request exports no instances.
        let mut steal = Json::obj();
        steal.set("thief", 1u64.into()).set("max", 4u64.into());
        let resp = http::post_json(&addr, "/federation/steal", &steal).unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp
            .json_body()
            .unwrap()
            .get("instances")
            .unwrap()
            .as_arr()
            .unwrap()
            .is_empty());
        // A completion report with no matching loan is dropped (settled
        // false), not an error.
        let mut done = Json::obj();
        done.set("run", 9u64.into())
            .set("function", "f".into())
            .set("instance", 0u64.into())
            .set("requeue", true.into());
        let resp = http::post_json(&addr, "/federation/complete", &done).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.json_body().unwrap().get("settled").unwrap().as_bool(), Some(false));
        let (_, _, merged, skipped) = fed.gossip_counters();
        assert_eq!((merged, skipped), (1, 1));
    }

    /// `fedapp` hashes to member 1 of 2 (see `Federation::owner_of_app`);
    /// `asyncdemo` to member 0. The fixture deploys a single-function app
    /// under either name.
    fn deploy_echo_app(bed: &crate::coordinator::resource::testkit::TestBed, app: &str) {
        bed.executor.register("img/echo-fed", |_: &[u8]| Ok(br#"{"outputs":[]}"#.to_vec()));
        let yaml = format!(
            "application: {app}\nentrypoint: f\ndag:\n  - name: f\n    affinity:\n      \
             nodetype: edge\n      affinitytype: data\n    reduce: 1\n"
        );
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![bed.iot[0]]);
        bed.faas.configure_application(&yaml, &data).unwrap();
        bed.faas
            .deploy_function(app, "f", &FunctionPackage { code: "img/echo-fed".into() })
            .unwrap();
    }

    #[test]
    fn federated_run_forwards_to_the_owner() {
        // Member 1 owns `fedapp` and hosts it; member 0 relays.
        let (owner_server, owner_bed) = served();
        Federation::enable(
            &owner_bed.faas,
            crate::coordinator::federation::FederationConfig::new(1, 2),
        )
        .unwrap();
        deploy_echo_app(&owner_bed, "fedapp");
        let (relay_server, relay_bed) = served();
        let relay_fed = Federation::enable(
            &relay_bed.faas,
            crate::coordinator::federation::FederationConfig::new(0, 2)
                .peer(1, owner_server.addr()),
        )
        .unwrap();
        let resp = http::post_json(
            &relay_server.addr(),
            "/apps/fedapp/run?priority=realtime",
            &Json::obj(),
        )
        .unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str().unwrap_or(""));
        let v = resp.json_body().unwrap();
        assert!(v.get("functions").unwrap().get("f").is_some());
        assert_eq!(relay_fed.forward_counters(), (1, 0));
        // One hop max: a marked relay landing on a non-owner is a typed
        // misroute.
        let resp = http::request(
            &relay_server.addr(),
            "POST",
            "/apps/fedapp/run?forwarded=1",
            &[],
            &[],
        )
        .unwrap();
        assert_eq!(resp.status, 421);
    }

    #[test]
    fn federated_run_degrades_to_local_service() {
        let (server, bed) = served();
        // Member 0 does not own `fedapp`, but with the owner's address
        // unknown the submission is served locally rather than dropped.
        let fed = Federation::enable(
            &bed.faas,
            crate::coordinator::federation::FederationConfig::new(0, 2),
        )
        .unwrap();
        assert!(fed.forward_target("fedapp").is_none());
        deploy_echo_app(&bed, "fedapp");
        let resp = http::post_json(&server.addr(), "/apps/fedapp/run", &Json::obj()).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str().unwrap_or(""));
        // An unreachable owner is a typed 502, counted as a failed forward.
        let fed = Federation::enable(
            &bed.faas,
            crate::coordinator::federation::FederationConfig::new(0, 2)
                .peer(1, "127.0.0.1:1"),
        )
        .unwrap();
        let resp = http::post_json(&server.addr(), "/apps/fedapp/run", &Json::obj()).unwrap();
        assert_eq!(resp.status, 502, "{}", resp.body_str().unwrap_or(""));
        let v = resp.json_body().unwrap();
        assert_eq!(v.get("connectivity").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("owner").unwrap().as_u64(), Some(1));
        assert_eq!(fed.forward_counters(), (0, 1));
    }

    #[test]
    fn storage_verbs_over_rest() {
        let (server, bed) = served();
        let addr = server.addr();
        let resp = http::request(
            &addr,
            "PUT",
            &format!("/apps/demo/buckets/data?locality={}", bed.cloud),
            &[],
            &[],
        )
        .unwrap();
        assert_eq!(resp.status, 201);
        let resp =
            http::request(&addr, "PUT", "/apps/demo/objects/data/hello.bin", &[], b"payload")
                .unwrap();
        assert_eq!(resp.status, 201);
        let url = resp.json_body().unwrap().req_str("url").unwrap().to_string();
        assert!(url.starts_with("demo/data/"));
        let resp = http::get(
            &addr,
            &format!("/objects?url={}", crate::util::http::url_encode(&url)),
        )
        .unwrap();
        assert_eq!(resp.body, b"payload");
        // Listing + deletion.
        let names = http::get(&addr, "/apps/demo/objects/data").unwrap().json_body().unwrap();
        assert_eq!(names.as_arr().unwrap().len(), 1);
        assert_eq!(
            http::delete(&addr, "/apps/demo/objects/data/hello.bin").unwrap().status,
            200
        );
        assert_eq!(http::delete(&addr, "/apps/demo/buckets/data").unwrap().status, 200);
    }
}
