//! Application configuration (the paper's Table 2 YAML schema).
//!
//! "Before the functions are created, the user needs to configure the
//! application first. A YAML file with the application's configuration is
//! provided" (§3.2). The schema:
//!
//! ```yaml
//! application: federatedlearning
//! entrypoint: train            # or a list of entrypoints
//! dag:
//!   - name: train
//!     dependencies:            # previous functions (empty for sources)
//!     requirements:
//!       memory: 1024MB
//!       gpu: 0
//!       privacy: 0             # 1 => IoT-only, where the data is generated
//!     affinity:
//!       nodetype: iot          # iot | edge | cloud
//!       affinitytype: data     # data | function (paper also spells this
//!                              #   field `nodelocation`; both accepted)
//!     reduce: auto             # 1 | auto
//! ```

use crate::simnet::Tier;
use crate::util::bytes::parse_size;
use crate::util::yaml::Yaml;

/// `affinitytype`: deploy relative to input data or to the dependency
/// function's placements (§3.2.2 point 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AffinityType {
    Data,
    Function,
}

/// `reduce`: how many instances of the function to deploy (§3.2.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduce {
    /// One instance, closest to *all* upstream locations.
    One,
    /// One instance per upstream location ("EdgeFaaS automatically finds the
    /// closest resource to each IoT device of the previous function").
    Auto,
}

/// Placement constraint (§3.2.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Affinity {
    pub nodetype: Tier,
    pub affinitytype: AffinityType,
}

/// Resource requirements (§3.2.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Requirements {
    /// Required memory per sandbox, bytes.
    pub memory: u64,
    /// Required GPU count.
    pub gpu: u32,
    /// 1 => may only run on the IoT devices where the input data is
    /// generated (privacy preservation by never moving the data).
    pub privacy: bool,
}

impl Default for Requirements {
    fn default() -> Self {
        Requirements { memory: 128 << 20, gpu: 0, privacy: false }
    }
}

/// One function's configuration within the application DAG.
#[derive(Debug, Clone, PartialEq)]
pub struct FunctionConfig {
    pub name: String,
    pub dependencies: Vec<String>,
    pub requirements: Requirements,
    pub affinity: Affinity,
    pub reduce: Reduce,
}

/// A parsed application configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AppConfig {
    pub application: String,
    pub entrypoints: Vec<String>,
    pub functions: Vec<FunctionConfig>,
}

impl AppConfig {
    /// Parse and validate a Table-2 YAML document.
    pub fn from_yaml(y: &Yaml) -> anyhow::Result<AppConfig> {
        let application = y.req_str("application")?.to_string();
        if application.is_empty() || application.contains('.') || application.contains('/') {
            anyhow::bail!("invalid application name `{application}`");
        }
        // "If multiple entrypoints are given, all the entrypoints will be
        // invoked at the same time."
        let entrypoints: Vec<String> = match y.get("entrypoint") {
            Some(Yaml::Scalar(s)) => vec![s.clone()],
            Some(Yaml::Seq(items)) => items
                .iter()
                .map(|i| {
                    i.as_str()
                        .map(String::from)
                        .ok_or_else(|| anyhow::anyhow!("non-scalar entrypoint"))
                })
                .collect::<anyhow::Result<_>>()?,
            _ => anyhow::bail!("missing entrypoint"),
        };
        let dag = y
            .get("dag")
            .and_then(Yaml::as_seq)
            .ok_or_else(|| anyhow::anyhow!("missing dag"))?;
        let functions = dag.iter().map(parse_function).collect::<anyhow::Result<Vec<_>>>()?;
        let cfg = AppConfig { application, entrypoints, functions };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural validation: unique names, known dependencies, entrypoints
    /// present, no dependency cycles (see [`super::dag`]).
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.functions.is_empty() {
            anyhow::bail!("dag has no functions");
        }
        let mut seen = std::collections::HashSet::new();
        for f in &self.functions {
            if f.name.is_empty() || f.name.contains('.') || f.name.contains('/') {
                anyhow::bail!("invalid function name `{}`", f.name);
            }
            if !seen.insert(f.name.as_str()) {
                anyhow::bail!("duplicate function `{}`", f.name);
            }
        }
        for f in &self.functions {
            for d in &f.dependencies {
                if !seen.contains(d.as_str()) {
                    anyhow::bail!("function `{}` depends on unknown `{d}`", f.name);
                }
            }
        }
        for e in &self.entrypoints {
            if !seen.contains(e.as_str()) {
                anyhow::bail!("entrypoint `{e}` is not in the dag");
            }
        }
        super::dag::Dag::build(self)?; // cycle check + topo order
        Ok(())
    }

    pub fn function(&self, name: &str) -> Option<&FunctionConfig> {
        self.functions.iter().find(|f| f.name == name)
    }

    /// Functions that depend on `name`.
    pub fn dependents(&self, name: &str) -> Vec<&FunctionConfig> {
        self.functions.iter().filter(|f| f.dependencies.iter().any(|d| d == name)).collect()
    }
}

fn parse_function(y: &Yaml) -> anyhow::Result<FunctionConfig> {
    let name = y.req_str("name")?.to_string();
    let dependencies = match y.get("dependencies") {
        None | Some(Yaml::Null) => Vec::new(),
        Some(Yaml::Scalar(s)) if s.trim().is_empty() => Vec::new(),
        // The paper writes a single dependency as a scalar; also accept a
        // comma list or a YAML sequence for fan-in.
        Some(Yaml::Scalar(s)) => s.split(',').map(|p| p.trim().to_string()).collect(),
        Some(Yaml::Seq(items)) => items
            .iter()
            .map(|i| {
                i.as_str().map(String::from).ok_or_else(|| anyhow::anyhow!("bad dependency"))
            })
            .collect::<anyhow::Result<_>>()?,
        Some(other) => anyhow::bail!("bad dependencies for `{name}`: {other:?}"),
    };
    let requirements = match y.get("requirements") {
        Some(r) => Requirements {
            memory: match r.get("memory").and_then(Yaml::as_str) {
                Some(s) => parse_size(s)?,
                None => Requirements::default().memory,
            },
            gpu: r.get("gpu").and_then(Yaml::as_i64).unwrap_or(0) as u32,
            privacy: r.get("privacy").and_then(Yaml::as_i64).unwrap_or(0) == 1,
        },
        None => Requirements::default(),
    };
    let affinity = {
        let a = y
            .get("affinity")
            .ok_or_else(|| anyhow::anyhow!("function `{name}` missing affinity"))?;
        let nodetype = Tier::parse(a.req_str("nodetype")?)?;
        // The paper's two YAML listings spell this field differently
        // (`affinitytype` in source code 1, `nodelocation` in source code 2).
        let at = a
            .get("affinitytype")
            .or_else(|| a.get("nodelocation"))
            .and_then(Yaml::as_str)
            .ok_or_else(|| anyhow::anyhow!("function `{name}` missing affinitytype"))?;
        let affinitytype = match at {
            "data" => AffinityType::Data,
            "function" => AffinityType::Function,
            other => anyhow::bail!("bad affinitytype `{other}` for `{name}`"),
        };
        Affinity { nodetype, affinitytype }
    };
    let reduce = match y.get("reduce").and_then(Yaml::as_str).unwrap_or("auto") {
        "1" => Reduce::One,
        "auto" => Reduce::Auto,
        other => anyhow::bail!("bad reduce `{other}` for `{name}` (expected 1|auto)"),
    };
    Ok(FunctionConfig { name, dependencies, requirements, affinity, reduce })
}

/// The paper's video-analytics configuration (source code 1), with the
/// placement tiers of Fig. 10 (the empirical optimum found in Fig. 9).
pub fn video_pipeline_yaml() -> &'static str {
    "\
application: videopipeline
entrypoint: video-generator
dag:
  - name: video-generator
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: video-processing
    dependencies: video-generator
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: auto
  - name: motion-detection
    dependencies: video-processing
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: auto
  - name: face-detection
    dependencies: motion-detection
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: auto
  - name: face-extraction
    dependencies: face-detection
    affinity:
      nodetype: cloud
      affinitytype: function
    reduce: auto
  - name: face-recognition
    dependencies: face-extraction
    affinity:
      nodetype: cloud
      affinitytype: function
    reduce: auto
"
}

/// The paper's federated-learning configuration (source code 2).
pub fn federated_learning_yaml() -> &'static str {
    "\
application: federatedlearning
entrypoint: train
dag:
  - name: train
    dependencies:
    requirements:
      memory: 1024MB
      gpu: 0
      privacy: 1
    affinity:
      nodetype: iot
      nodelocation: data
    reduce: auto
  - name: firstaggregation
    dependencies: train
    affinity:
      nodetype: edge
      nodelocation: function
    reduce: auto
  - name: secondaggregation
    dependencies: firstaggregation
    affinity:
      nodetype: cloud
      nodelocation: function
    reduce: 1
"
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::yaml;

    #[test]
    fn parses_federated_learning_yaml() {
        let cfg = AppConfig::from_yaml(&yaml::parse(federated_learning_yaml()).unwrap()).unwrap();
        assert_eq!(cfg.application, "federatedlearning");
        assert_eq!(cfg.entrypoints, vec!["train"]);
        assert_eq!(cfg.functions.len(), 3);
        let train = cfg.function("train").unwrap();
        assert!(train.dependencies.is_empty());
        assert!(train.requirements.privacy);
        assert_eq!(train.requirements.memory, 1 << 30);
        assert_eq!(train.affinity.nodetype, Tier::Iot);
        assert_eq!(train.affinity.affinitytype, AffinityType::Data);
        assert_eq!(train.reduce, Reduce::Auto);
        let agg2 = cfg.function("secondaggregation").unwrap();
        assert_eq!(agg2.reduce, Reduce::One);
        assert_eq!(agg2.dependencies, vec!["firstaggregation"]);
    }

    #[test]
    fn parses_video_pipeline_yaml() {
        let cfg = AppConfig::from_yaml(&yaml::parse(video_pipeline_yaml()).unwrap()).unwrap();
        assert_eq!(cfg.functions.len(), 6);
        assert_eq!(cfg.function("video-generator").unwrap().affinity.affinitytype, AffinityType::Data);
        assert_eq!(cfg.function("face-recognition").unwrap().affinity.nodetype, Tier::Cloud);
        assert_eq!(cfg.dependents("motion-detection").len(), 1);
    }

    #[test]
    fn multiple_entrypoints() {
        let doc = "\
application: multi
entrypoint:
  - a
  - b
dag:
  - name: a
    affinity: {nope: 0}
";
        // flow-style affinity is unsupported -> function parsing must fail
        assert!(AppConfig::from_yaml(&yaml::parse(doc).unwrap()).is_err());
        let doc = "\
application: multi
entrypoint:
  - a
  - b
dag:
  - name: a
    affinity:
      nodetype: iot
      affinitytype: data
  - name: b
    affinity:
      nodetype: edge
      affinitytype: data
";
        let cfg = AppConfig::from_yaml(&yaml::parse(doc).unwrap()).unwrap();
        assert_eq!(cfg.entrypoints, vec!["a", "b"]);
    }

    #[test]
    fn rejects_structural_errors() {
        // Unknown dependency.
        let doc = "\
application: bad
entrypoint: a
dag:
  - name: a
    dependencies: ghost
    affinity:
      nodetype: iot
      affinitytype: data
";
        assert!(AppConfig::from_yaml(&yaml::parse(doc).unwrap()).is_err());
        // Duplicate function.
        let doc = "\
application: bad
entrypoint: a
dag:
  - name: a
    affinity:
      nodetype: iot
      affinitytype: data
  - name: a
    affinity:
      nodetype: iot
      affinitytype: data
";
        assert!(AppConfig::from_yaml(&yaml::parse(doc).unwrap()).is_err());
        // Missing entrypoint in dag.
        let doc = "\
application: bad
entrypoint: ghost
dag:
  - name: a
    affinity:
      nodetype: iot
      affinitytype: data
";
        assert!(AppConfig::from_yaml(&yaml::parse(doc).unwrap()).is_err());
        // Dependency cycle.
        let doc = "\
application: bad
entrypoint: a
dag:
  - name: a
    dependencies: b
    affinity:
      nodetype: iot
      affinitytype: data
  - name: b
    dependencies: a
    affinity:
      nodetype: iot
      affinitytype: data
";
        assert!(AppConfig::from_yaml(&yaml::parse(doc).unwrap()).is_err());
    }

    #[test]
    fn fan_in_dependency_list() {
        let doc = "\
application: join
entrypoint: a
dag:
  - name: a
    affinity:
      nodetype: iot
      affinitytype: data
  - name: b
    affinity:
      nodetype: iot
      affinitytype: data
  - name: j
    dependencies: a, b
    affinity:
      nodetype: cloud
      affinitytype: function
";
        let cfg = AppConfig::from_yaml(&yaml::parse(doc).unwrap()).unwrap();
        assert_eq!(cfg.function("j").unwrap().dependencies, vec!["a", "b"]);
    }

    #[test]
    fn defaults_applied() {
        let doc = "\
application: app
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: cloud
      affinitytype: data
";
        let cfg = AppConfig::from_yaml(&yaml::parse(doc).unwrap()).unwrap();
        let f = cfg.function("f").unwrap();
        assert_eq!(f.requirements, Requirements::default());
        assert_eq!(f.reduce, Reduce::Auto);
    }
}
