//! Multi-coordinator federation: N coordinators jointly serving one
//! logical fleet, each *owning* a slice of the resource map.
//!
//! The engine is shard-structured and scheduling reads are snapshot-local,
//! so one coordinator scales a long way *up* — this module scales the
//! control plane *out*, mirroring EDGELESS's two-level ε-CON (across
//! orchestration domains) / ε-ORC (within a domain) split. Every member
//! registers the *same* resources in the same order (identical resource
//! ids fleet-wide); membership assigns each coordinator the slices it is
//! responsible for:
//!
//! * resource `r` is owned by member `r % members` — its owner scrapes and
//!   lease-steps it ([`EdgeFaaS::refresh_monitor_snapshot`]'s scoped
//!   variant), and only the owner's detector can declare it `Dead`
//!   fleet-wide;
//! * application `a` is owned by member `fnv1a(a) % members` — apps are
//!   configured and deployed on their owner, and submissions arriving
//!   elsewhere are forwarded there (one hop max; see the gateway's
//!   `POST /apps/{app}/run`).
//!
//! Three mechanisms connect the members, all over the pooled keep-alive
//! HTTP client with the short [`VerbBudgets::federation`] budget:
//!
//! 1. **Epoch-merged snapshot gossip.** Each tick a coordinator sweeps its
//!    owned slice, then pushes its `MonitorSnapshot` view (usage samples +
//!    leases, restricted to its owned resources plus any non-owned lease
//!    it holds adverse evidence about) to every peer
//!    (`POST /federation/gossip`). Receivers gate by `(sender, epoch)` —
//!    stale or replayed pushes are skipped — and merge through
//!    [`EdgeFaaS::merge_federated_view`]: usage adopts the newer sample,
//!    leases are owner-authoritative, and a non-owner's worse opinion caps
//!    at `Suspect` (pessimistic, but hearsay never drains). Phase-1
//!    placement onto a peer's resources then needs *zero* remote scrapes,
//!    and a merge that changed no lease state re-keys the placement
//!    decision cache instead of invalidating it, so cached decisions stay
//!    valid across merged epochs.
//!
//! 2. **Submission forwarding.** A gateway receiving `POST
//!    /apps/{app}/run` for an app it does not own relays it to the owner,
//!    preserving QoS class and the *remaining* deadline budget. The relay
//!    carries a one-hop marker so a misconfigured fleet degrades to a
//!    typed error, never a forwarding loop; a connectivity failure
//!    surfaces as a typed 502 with the `HttpError` chain.
//!
//! 3. **Work stealing.** An idle coordinator polls peers' `GET
//!    /engine/stats` for per-shard queue depths; finding one overloaded,
//!    it pulls up to a shard's worth of *queued* instances via `POST
//!    /federation/steal`. The victim records each exported instance as a
//!    **loan** and the thief executes it on its own schedulable resources
//!    (preferring the original anchor, which it also has registered),
//!    reporting the outcome back (`POST /federation/complete`) so the
//!    victim's run bookkeeping completes exactly as if it had dispatched
//!    locally. Attempt ids travel with the loan: if the thief dies or
//!    partitions mid-steal, the victim reclaims the loan after
//!    [`FederationConfig::reclaim_s`] and re-enqueues it with the *same*
//!    attempt id, so the backend's attempt cache keeps the
//!    execute-vs-reclaim race at-most-once.
//!
//! Partition behaviour: gossip pushes and steal polls fail fast on their
//! federation budget and count failures; submissions keep flowing on every
//! member for the apps it owns (owner-local degradation). Healing needs no
//! protocol — the next successful push re-merges, and outstanding loans
//! either complete late (dropped: the loan was already reclaimed, and the
//! dedup cache absorbed any double execution) or reclaim.
//!
//! Everything here is driven by [`Federation::tick`] — call it directly
//! under virtual clocks (deterministic tests), or let
//! [`Federation::start`] run it on a background thread (wire benches,
//! real deployments).

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::monitor::liveness::{LeaseState, ResourceLease};
use crate::monitor::metrics::ResourceUsage;
use crate::monitor::snapshot::UsageSample;
use crate::util::bytes::Bytes;
use crate::util::http::{self, RequestOptions};
use crate::util::json::{self, Json};

use super::engine::{patch_envelope_resource, Priority, QoS, RunId, StolenInstance};
use super::handle::VerbBudgets;
use super::invoker::{parse_outputs, InstanceResult};
use super::resource::{EdgeFaaS, ResourceId};
use crate::cluster::faas::BatchCall;

/// One peer coordinator: member id + gateway address (`host:port`).
#[derive(Debug, Clone)]
pub struct PeerSpec {
    pub id: u32,
    pub addr: String,
}

/// Federation membership + tuning for one coordinator.
#[derive(Debug, Clone)]
pub struct FederationConfig {
    /// This coordinator's member id (`0..members`).
    pub self_id: u32,
    /// Total member count (including self). Resource `r` is owned by
    /// member `r % members`; app `a` by `fnv1a(a) % members`.
    pub members: u32,
    /// The other members' gateway addresses. May be incomplete (a member
    /// without a known address is simply never pushed to or stolen from).
    pub peers: Vec<PeerSpec>,
    /// Deepest-shard queue depth at which a peer counts as overloaded
    /// (steal trigger).
    pub steal_threshold: usize,
    /// Most instances pulled per steal (also the victim-side export cap).
    pub steal_max: usize,
    /// Most *local* queued instances a coordinator may have and still
    /// consider itself idle enough to steal.
    pub steal_idle_max: usize,
    /// Seconds before an unacknowledged loan is reclaimed and re-enqueued
    /// locally. Generous by default: a reclaim racing a slow thief is
    /// deduplicated at the backend, but only when the anchor backend is
    /// shared — keep this above the worst-case steal round trip.
    pub reclaim_s: f64,
}

impl FederationConfig {
    /// Defaults for a `members`-coordinator fleet, no peer addresses yet.
    pub fn new(self_id: u32, members: u32) -> FederationConfig {
        FederationConfig {
            self_id,
            members,
            peers: Vec::new(),
            steal_threshold: 8,
            steal_max: 16,
            steal_idle_max: 1,
            reclaim_s: 30.0,
        }
    }

    /// Add a peer address (builder style).
    pub fn peer(mut self, id: u32, addr: impl Into<String>) -> FederationConfig {
        self.peers.push(PeerSpec { id, addr: addr.into() });
        self
    }
}

/// FNV-1a over the app name — the consistent app→owner mapping every
/// member computes identically (same constants as the population digests).
fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// One coordinator's federation runtime: membership, gossip/steal
/// counters, and the per-peer merge gate. Attached to the coordinator by
/// [`Federation::enable`]; holds only a `Weak` back-reference, so dropping
/// the coordinator also retires its federation driver.
pub struct Federation {
    cfg: FederationConfig,
    faas: Weak<EdgeFaaS>,
    /// Last merged snapshot epoch per sender — the gossip replay gate.
    merged_epoch: Mutex<HashMap<u32, u64>>,
    gossip_pushed: AtomicU64,
    gossip_push_failures: AtomicU64,
    gossip_merged: AtomicU64,
    gossip_skipped: AtomicU64,
    forwards: AtomicU64,
    forward_failures: AtomicU64,
    steal_polls: AtomicU64,
    steal_hits: AtomicU64,
    instances_stolen: AtomicU64,
    stolen_executed: AtomicU64,
    stolen_returned: AtomicU64,
    complete_push_failures: AtomicU64,
    driver_stop: AtomicBool,
    driver_running: AtomicBool,
}

impl Federation {
    /// Validate `cfg` and attach a federation runtime to `faas`
    /// (reachable afterwards through `EdgeFaaS::federation`). Does not
    /// start the background driver — call [`Federation::start`], or drive
    /// [`Federation::tick`] manually under a virtual clock.
    pub fn enable(faas: &Arc<EdgeFaaS>, cfg: FederationConfig) -> anyhow::Result<Arc<Federation>> {
        anyhow::ensure!(cfg.members >= 1, "federation needs at least one member");
        anyhow::ensure!(
            cfg.self_id < cfg.members,
            "self_id {} out of range for {} member(s)",
            cfg.self_id,
            cfg.members
        );
        let mut seen = BTreeSet::new();
        for p in &cfg.peers {
            anyhow::ensure!(p.id != cfg.self_id, "peer id {} is self", p.id);
            anyhow::ensure!(
                p.id < cfg.members,
                "peer id {} out of range for {} member(s)",
                p.id,
                cfg.members
            );
            anyhow::ensure!(seen.insert(p.id), "duplicate peer id {}", p.id);
        }
        let fed = Arc::new(Federation {
            cfg,
            faas: Arc::downgrade(faas),
            merged_epoch: Mutex::new(HashMap::new()),
            gossip_pushed: AtomicU64::new(0),
            gossip_push_failures: AtomicU64::new(0),
            gossip_merged: AtomicU64::new(0),
            gossip_skipped: AtomicU64::new(0),
            forwards: AtomicU64::new(0),
            forward_failures: AtomicU64::new(0),
            steal_polls: AtomicU64::new(0),
            steal_hits: AtomicU64::new(0),
            instances_stolen: AtomicU64::new(0),
            stolen_executed: AtomicU64::new(0),
            stolen_returned: AtomicU64::new(0),
            complete_push_failures: AtomicU64::new(0),
            driver_stop: AtomicBool::new(false),
            driver_running: AtomicBool::new(false),
        });
        *faas.federation.write().unwrap() = Some(Arc::clone(&fed));
        Ok(fed)
    }

    pub fn config(&self) -> &FederationConfig {
        &self.cfg
    }

    // -------------------------------------------------------- ownership --

    /// The member owning application `app` (consistent across members).
    pub fn owner_of_app(&self, app: &str) -> u32 {
        (fnv1a64(app) % self.cfg.members.max(1) as u64) as u32
    }

    pub fn owns_app(&self, app: &str) -> bool {
        self.owner_of_app(app) == self.cfg.self_id
    }

    /// The member owning resource `rid` (consistent because every member
    /// registers the same resources in the same order).
    pub fn owner_of_resource(&self, rid: ResourceId) -> u32 {
        rid % self.cfg.members.max(1)
    }

    pub fn owns_resource(&self, rid: ResourceId) -> bool {
        self.owner_of_resource(rid) == self.cfg.self_id
    }

    /// The registered resources this coordinator owns.
    pub fn owned_resources(&self, faas: &EdgeFaaS) -> BTreeSet<ResourceId> {
        faas.resource_ids().into_iter().filter(|&r| self.owns_resource(r)).collect()
    }

    /// A peer's gateway address, when known.
    pub fn peer_addr(&self, id: u32) -> Option<&str> {
        self.cfg.peers.iter().find(|p| p.id == id).map(|p| p.addr.as_str())
    }

    /// Where `POST /apps/{app}/run` must forward: the owner's address, or
    /// `None` when this coordinator owns the app (or the owner's address
    /// is unknown — serve locally rather than black-hole).
    pub fn forward_target(&self, app: &str) -> Option<&str> {
        let owner = self.owner_of_app(app);
        if owner == self.cfg.self_id {
            return None;
        }
        self.peer_addr(owner)
    }

    /// Count a forward attempt (gateway-side bookkeeping).
    pub fn note_forward(&self, ok: bool) {
        if ok {
            self.forwards.fetch_add(1, Ordering::Relaxed);
        } else {
            self.forward_failures.fetch_add(1, Ordering::Relaxed);
        }
    }

    // ----------------------------------------------------------- gossip --

    /// Sweep (scrape + lease-step) only the owned slice, carrying peers'
    /// entries forward untouched. Returns the published epoch (0 when the
    /// coordinator is gone).
    pub fn sweep_owned(&self) -> u64 {
        let Some(faas) = self.faas.upgrade() else { return 0 };
        let owned = self.owned_resources(&faas);
        faas.refresh_monitor_snapshot_scoped(Some(&owned))
    }

    /// This coordinator's gossip payload: its snapshot view restricted to
    /// the resources it owns (authoritative), plus any non-owned lease it
    /// holds adverse (non-`Alive`) evidence about — the warning channel
    /// behind the receiver's pessimistic `Suspect` cap.
    pub fn export_view(&self) -> anyhow::Result<Json> {
        let faas = self.faas.upgrade().ok_or_else(|| anyhow::anyhow!("coordinator gone"))?;
        let snap = faas.monitor_snapshot();
        let owned = self.owned_resources(&faas);
        let mut usage = Json::obj();
        for (rid, sample) in snap.samples() {
            if owned.contains(&rid) {
                usage.set(&rid.to_string(), usage_to_json(sample));
            }
        }
        let mut leases = Json::obj();
        for (rid, lease) in snap.leases() {
            if owned.contains(&rid) || lease.state != LeaseState::Alive {
                leases.set(&rid.to_string(), lease_to_json(lease));
            }
        }
        let mut v = Json::obj();
        v.set("from", (self.cfg.self_id as u64).into())
            .set("epoch", snap.epoch.into())
            .set("taken_at", snap.taken_at.into())
            .set("owned", Json::Arr(owned.iter().map(|&r| (r as u64).into()).collect()))
            .set("usage", usage)
            .set("leases", leases);
        Ok(v)
    }

    /// Push the current view to every known peer. Returns
    /// `(delivered, failed)`; failures are counted, logged and otherwise
    /// ignored (the next tick pushes a fresher epoch anyway).
    pub fn push_gossip(&self) -> (usize, usize) {
        let Ok(view) = self.export_view() else { return (0, 0) };
        let body = view.to_string();
        let (mut delivered, mut failed) = (0usize, 0usize);
        for peer in &self.cfg.peers {
            match self.peer_post_raw(&peer.addr, "/federation/gossip", body.as_bytes()) {
                Ok(()) => {
                    self.gossip_pushed.fetch_add(1, Ordering::Relaxed);
                    delivered += 1;
                }
                Err(e) => {
                    self.gossip_push_failures.fetch_add(1, Ordering::Relaxed);
                    failed += 1;
                    log::debug!(
                        "federation {}: gossip push to {} failed: {e}",
                        self.cfg.self_id,
                        peer.addr
                    );
                }
            }
        }
        (delivered, failed)
    }

    /// Receive a peer's gossip push (`POST /federation/gossip`). Returns
    /// `Ok(None)` when the push was skipped as stale (the sender's epoch
    /// was already merged), `Ok(Some(local_epoch))` after a merge.
    pub fn receive_gossip(&self, body: &Json) -> anyhow::Result<Option<u64>> {
        let faas = self.faas.upgrade().ok_or_else(|| anyhow::anyhow!("coordinator gone"))?;
        let from = body
            .get("from")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("gossip: missing `from`"))? as u32;
        anyhow::ensure!(from != self.cfg.self_id, "gossip: from self");
        let epoch = body.get("epoch").and_then(Json::as_u64).unwrap_or(0);
        {
            // Replay/staleness gate, per sender: snapshot epochs are
            // strictly increasing on each coordinator.
            let mut merged = self.merged_epoch.lock().unwrap();
            if merged.get(&from).is_some_and(|&last| epoch <= last) {
                self.gossip_skipped.fetch_add(1, Ordering::Relaxed);
                return Ok(None);
            }
            merged.insert(from, epoch);
        }
        let mut authoritative = BTreeSet::new();
        if let Some(owned) = body.get("owned").and_then(Json::as_arr) {
            for v in owned {
                if let Some(r) = v.as_u64() {
                    authoritative.insert(r as ResourceId);
                }
            }
        }
        let mut usage = BTreeMap::new();
        if let Some(Json::Obj(m)) = body.get("usage") {
            for (k, v) in m {
                if let (Ok(rid), Some(s)) = (k.parse::<ResourceId>(), usage_from_json(v)) {
                    usage.insert(rid, s);
                }
            }
        }
        let mut leases = BTreeMap::new();
        if let Some(Json::Obj(m)) = body.get("leases") {
            for (k, v) in m {
                if let (Ok(rid), Some(l)) = (k.parse::<ResourceId>(), lease_from_json(v)) {
                    leases.insert(rid, l);
                }
            }
        }
        let local = faas.merge_federated_view(&authoritative, &usage, &leases);
        self.gossip_merged.fetch_add(1, Ordering::Relaxed);
        Ok(Some(local))
    }

    /// Mean age (seconds) of the non-owned usage samples in the local
    /// snapshot — how stale the gossiped view of peers' slices is. `None`
    /// until a merge delivered at least one non-owned sample.
    pub fn gossip_staleness(&self) -> Option<f64> {
        let faas = self.faas.upgrade()?;
        let snap = faas.monitor_snapshot();
        let owned = self.owned_resources(&faas);
        let now = faas.clock().now();
        let (mut sum, mut n) = (0.0f64, 0usize);
        for (rid, s) in snap.samples() {
            if !owned.contains(&rid) {
                sum += (now - s.collected_at).max(0.0);
                n += 1;
            }
        }
        (n > 0).then(|| sum / n as f64)
    }

    // ----------------------------------------------------- work stealing --

    /// Victim side of `POST /federation/steal`: export up to
    /// `min(requested, steal_max)` queued instances from the deepest
    /// dispatch shard as loans.
    pub fn serve_steal(&self, requested: usize) -> anyhow::Result<Json> {
        let faas = self.faas.upgrade().ok_or_else(|| anyhow::anyhow!("coordinator gone"))?;
        let exported =
            faas.export_stealable(requested.min(self.cfg.steal_max), self.cfg.reclaim_s);
        let mut v = Json::obj();
        v.set("instances", Json::Arr(exported.iter().map(stolen_to_json).collect()));
        Ok(v)
    }

    /// Thief side: if locally idle, poll peers for overload (deepest-shard
    /// queue depth ≥ `steal_threshold`) and pull one batch of instances
    /// from the first overloaded peer. Returns the number absorbed.
    pub fn steal_once(self: &Arc<Self>) -> usize {
        let Some(faas) = self.faas.upgrade() else { return 0 };
        let local: usize = faas.shard_queue_depths().iter().sum();
        if local > self.cfg.steal_idle_max {
            return 0;
        }
        for peer in &self.cfg.peers {
            self.steal_polls.fetch_add(1, Ordering::Relaxed);
            let Ok(depth) = self.peer_queue_depth(&peer.addr) else { continue };
            if depth < self.cfg.steal_threshold.max(1) {
                continue;
            }
            match self.steal_from(&faas, &peer.addr) {
                Ok(n) if n > 0 => {
                    self.steal_hits.fetch_add(1, Ordering::Relaxed);
                    return n;
                }
                Ok(_) => {}
                Err(e) => log::debug!(
                    "federation {}: steal from {} failed: {e}",
                    self.cfg.self_id,
                    peer.addr
                ),
            }
        }
        0
    }

    /// A peer's deepest-shard queued-instance depth (falls back to the
    /// global counter for pre-federation gateways).
    fn peer_queue_depth(&self, addr: &str) -> anyhow::Result<usize> {
        let resp = http::request_with(
            addr,
            "GET",
            "/engine/stats",
            &[],
            &[],
            RequestOptions::with_deadline(VerbBudgets::default().federation),
        )?;
        anyhow::ensure!(resp.status == 200, "GET {addr}/engine/stats: status {}", resp.status);
        let v = json::parse(std::str::from_utf8(&resp.body)?)?;
        if let Some(depths) = v.get("queue_depths").and_then(Json::as_arr) {
            return Ok(depths.iter().filter_map(Json::as_u64).max().unwrap_or(0) as usize);
        }
        Ok(v.get("queued_instances").and_then(Json::as_u64).unwrap_or(0) as usize)
    }

    fn steal_from(self: &Arc<Self>, faas: &Arc<EdgeFaaS>, victim: &str) -> anyhow::Result<usize> {
        let mut req = Json::obj();
        req.set("thief", (self.cfg.self_id as u64).into())
            .set("max", self.cfg.steal_max.into());
        let resp = http::request_with(
            victim,
            "POST",
            "/federation/steal",
            &[("Content-Type", "application/json")],
            req.to_string().as_bytes(),
            RequestOptions::with_deadline(VerbBudgets::default().federation),
        )?;
        anyhow::ensure!(resp.status == 200, "POST {victim}/federation/steal: status {}", resp.status);
        let v = json::parse(std::str::from_utf8(&resp.body)?)?;
        let instances = v.get("instances").and_then(Json::as_arr).unwrap_or(&[]);
        let mut absorbed = 0usize;
        for item in instances {
            let st = match stolen_from_json(item) {
                Ok(s) => s,
                Err(e) => {
                    // Dropped, not lost: the victim's loan reclaim covers it.
                    log::warn!("federation: dropping malformed stolen instance: {e}");
                    continue;
                }
            };
            self.instances_stolen.fetch_add(1, Ordering::Relaxed);
            let fed = Arc::clone(self);
            let victim = victim.to_string();
            let qos = QoS { priority: st.class, deadline_s: st.remaining_s };
            faas.spawn_job_qos(qos, move |faas| fed.execute_stolen(faas, &victim, st));
            absorbed += 1;
        }
        Ok(absorbed)
    }

    /// Execute one stolen instance on this coordinator's resources and
    /// report the outcome to the victim. Target preference: the original
    /// anchor (registered here too — same backend, so the shared attempt
    /// cache covers any reclaim race), else the first schedulable
    /// candidate this coordinator knows, else return the instance
    /// unexecuted (`requeue`).
    fn execute_stolen(self: &Arc<Self>, faas: &Arc<EdgeFaaS>, victim: &str, st: StolenInstance) {
        let snap = faas.monitor_snapshot();
        let schedulable = |rid: ResourceId| {
            faas.resource(rid).is_ok()
                && snap.lease_of(rid).map(|l| l.state.schedulable()).unwrap_or(true)
        };
        let target = if schedulable(st.resource) {
            Some(st.resource)
        } else {
            faas.candidates_of(&st.app, &st.function)
                .unwrap_or_default()
                .into_iter()
                .find(|&r| schedulable(r))
        };
        let mut report = Json::obj();
        report
            .set("run", st.run.into())
            .set("function", st.function.as_str().into())
            .set("instance", st.instance.into());
        match target {
            None => {
                self.stolen_returned.fetch_add(1, Ordering::Relaxed);
                report.set("requeue", true.into());
            }
            Some(rid) => {
                self.stolen_executed.fetch_add(1, Ordering::Relaxed);
                match Self::invoke_stolen(faas, rid, &st) {
                    Ok(res) => {
                        report
                            .set("ok", true.into())
                            .set("resource", (res.resource as u64).into())
                            .set("latency", res.latency.into())
                            .set(
                                "outputs",
                                Json::Arr(
                                    res.outputs.iter().map(|o| o.as_str().into()).collect(),
                                ),
                            );
                    }
                    Err(e) => {
                        report
                            .set("ok", false.into())
                            .set("resource", (rid as u64).into())
                            .set("error", e.to_string().into());
                    }
                }
            }
        }
        if let Err(e) =
            self.peer_post_raw(victim, "/federation/complete", report.to_string().as_bytes())
        {
            // The victim reclaims the loan by timeout; if we executed, the
            // attempt cache absorbs its re-execution.
            self.complete_push_failures.fetch_add(1, Ordering::Relaxed);
            log::warn!(
                "federation {}: completion report to {victim} failed: {e}",
                self.cfg.self_id
            );
        }
    }

    fn invoke_stolen(
        faas: &Arc<EdgeFaaS>,
        rid: ResourceId,
        st: &StolenInstance,
    ) -> anyhow::Result<InstanceResult> {
        let reg = faas.resource(rid)?;
        let qname = EdgeFaaS::qualified(&st.app, &st.function);
        let envelope = if rid == st.resource {
            st.envelope.clone()
        } else {
            patch_envelope_resource(&st.envelope, rid)
        };
        let calls = [BatchCall {
            name: qname,
            payload: envelope,
            attempt: st.attempt,
            budget: st
                .remaining_s
                .map(|s| std::time::Duration::from_secs_f64(s.max(1e-9))),
        }];
        let mut results = reg.handle.invoke_batch(&calls);
        anyhow::ensure!(results.len() == 1, "backend returned {} results for 1 call", results.len());
        let (out, latency) = results.pop().expect("length checked")?;
        let outputs = parse_outputs(&out)?;
        Ok(InstanceResult { resource: rid, outputs, latency })
    }

    /// Victim side of `POST /federation/complete`: settle the loan.
    /// Returns whether a matching loan was outstanding (a `false` means
    /// the report arrived after a reclaim and was dropped).
    pub fn receive_complete(&self, v: &Json) -> anyhow::Result<bool> {
        let faas = self.faas.upgrade().ok_or_else(|| anyhow::anyhow!("coordinator gone"))?;
        let run: RunId = v
            .get("run")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("complete: missing `run`"))?;
        let function = v
            .get("function")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("complete: missing `function`"))?;
        let instance = v
            .get("instance")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("complete: missing `instance`"))?
            as usize;
        if v.get("requeue").and_then(Json::as_bool).unwrap_or(false) {
            let outcome = Err(anyhow::anyhow!("returned unexecuted by thief"));
            return Ok(faas.complete_remote_instance(run, function, instance, outcome, true));
        }
        let outcome = if v.get("ok").and_then(Json::as_bool).unwrap_or(false) {
            Ok(InstanceResult {
                resource: v.get("resource").and_then(Json::as_u64).unwrap_or(0) as ResourceId,
                latency: v.get("latency").and_then(Json::as_f64).unwrap_or(0.0),
                outputs: v
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
                    .unwrap_or_default(),
            })
        } else {
            Err(anyhow::anyhow!(
                "remote execution failed: {}",
                v.get("error").and_then(Json::as_str).unwrap_or("unknown error")
            ))
        };
        Ok(faas.complete_remote_instance(run, function, instance, outcome, false))
    }

    /// Re-enqueue loans past their reclaim deadline (thief died or
    /// partitioned mid-steal). Returns the number reclaimed.
    pub fn reclaim(&self) -> usize {
        match self.faas.upgrade() {
            Some(faas) => faas.reclaim_lent(),
            None => 0,
        }
    }

    // ----------------------------------------------------------- driver --

    /// One federation cycle: sweep the owned slice, push gossip, reclaim
    /// expired loans, then steal if idle. Deterministic tests call this
    /// directly; [`Federation::start`] runs it on an interval.
    pub fn tick(self: &Arc<Self>) {
        self.sweep_owned();
        self.push_gossip();
        self.reclaim();
        self.steal_once();
    }

    /// Run [`Federation::tick`] every `interval_s` on a background thread
    /// (clock-generic, like the monitor collector). Returns `false` if a
    /// driver is already running or the thread could not spawn. The
    /// thread holds only a `Weak` coordinator reference.
    pub fn start(self: &Arc<Self>, interval_s: f64) -> bool {
        if self.driver_running.swap(true, Ordering::SeqCst) {
            return false;
        }
        self.driver_stop.store(false, Ordering::SeqCst);
        let Some(faas) = self.faas.upgrade() else {
            self.driver_running.store(false, Ordering::SeqCst);
            return false;
        };
        let clock = Arc::clone(faas.clock());
        drop(faas);
        let weak: Weak<Federation> = Arc::downgrade(self);
        let interval = interval_s.max(0.0);
        let spawned = std::thread::Builder::new()
            .name(format!("federation-{}", self.cfg.self_id))
            .spawn(move || loop {
                let Some(fed) = weak.upgrade() else { break };
                if fed.driver_stop.load(Ordering::SeqCst) {
                    fed.driver_running.store(false, Ordering::SeqCst);
                    break;
                }
                fed.tick();
                drop(fed);
                clock.sleep(interval);
            });
        if spawned.is_err() {
            self.driver_running.store(false, Ordering::SeqCst);
            return false;
        }
        true
    }

    /// Signal the driver to stop after its current cycle.
    pub fn stop(&self) {
        self.driver_stop.store(true, Ordering::SeqCst);
    }

    // ------------------------------------------------------------- stats --

    /// `(pushed, push_failures, merged, skipped)` gossip counters.
    pub fn gossip_counters(&self) -> (u64, u64, u64, u64) {
        (
            self.gossip_pushed.load(Ordering::Relaxed),
            self.gossip_push_failures.load(Ordering::Relaxed),
            self.gossip_merged.load(Ordering::Relaxed),
            self.gossip_skipped.load(Ordering::Relaxed),
        )
    }

    /// `(polls, hits, instances_stolen, executed, returned)` thief-side
    /// steal counters.
    pub fn steal_counters(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.steal_polls.load(Ordering::Relaxed),
            self.steal_hits.load(Ordering::Relaxed),
            self.instances_stolen.load(Ordering::Relaxed),
            self.stolen_executed.load(Ordering::Relaxed),
            self.stolen_returned.load(Ordering::Relaxed),
        )
    }

    /// `(forwards, forward_failures)` gateway forwarding counters.
    pub fn forward_counters(&self) -> (u64, u64) {
        (self.forwards.load(Ordering::Relaxed), self.forward_failures.load(Ordering::Relaxed))
    }

    /// The full counter set as JSON (`GET /federation/stats`).
    pub fn stats_json(&self) -> Json {
        let (pushed, push_failed, merged, skipped) = self.gossip_counters();
        let (polls, hits, stolen, executed, returned) = self.steal_counters();
        let (forwards, forward_failures) = self.forward_counters();
        let mut v = Json::obj();
        v.set("self_id", (self.cfg.self_id as u64).into())
            .set("members", (self.cfg.members as u64).into())
            .set("gossip_pushed", pushed.into())
            .set("gossip_push_failures", push_failed.into())
            .set("gossip_merged", merged.into())
            .set("gossip_skipped", skipped.into())
            .set("forwards", forwards.into())
            .set("forward_failures", forward_failures.into())
            .set("steal_polls", polls.into())
            .set("steal_hits", hits.into())
            .set("instances_stolen", stolen.into())
            .set("stolen_executed", executed.into())
            .set("stolen_returned", returned.into())
            .set(
                "complete_push_failures",
                self.complete_push_failures.load(Ordering::Relaxed).into(),
            );
        if let Some(staleness) = self.gossip_staleness() {
            v.set("gossip_staleness_s", staleness.into());
        }
        if let Some(faas) = self.faas.upgrade() {
            let (lent, completed, requeued, reclaimed, outstanding) = faas.federation_loans();
            v.set("instances_lent", lent.into())
                .set("lent_completed", completed.into())
                .set("lent_requeued", requeued.into())
                .set("lent_reclaimed", reclaimed.into())
                .set("loans_outstanding", outstanding.into());
        }
        v
    }

    // ------------------------------------------------------------- wire --

    fn peer_post_raw(&self, addr: &str, path: &str, body: &[u8]) -> anyhow::Result<()> {
        let resp = http::request_with(
            addr,
            "POST",
            path,
            &[("Content-Type", "application/json")],
            body,
            RequestOptions::with_deadline(VerbBudgets::default().federation),
        )?;
        anyhow::ensure!(resp.status == 200, "POST {addr}{path}: status {}", resp.status);
        Ok(())
    }
}

// ------------------------------------------------------ wire (de)coding --

fn usage_to_json(s: &UsageSample) -> Json {
    let mut v = Json::obj();
    v.set("cpu_frac", s.usage.cpu_frac.into())
        .set("mem_used", s.usage.mem_used.into())
        .set("mem_total", s.usage.mem_total.into())
        .set("io_bytes_per_s", s.usage.io_bytes_per_s.into())
        .set("gpu_frac", s.usage.gpu_frac.into())
        .set("gpus_used", (s.usage.gpus_used as u64).into())
        .set("gpus_total", (s.usage.gpus_total as u64).into())
        .set("collected_at", s.collected_at.into())
        .set("consecutive_failures", (s.consecutive_failures as u64).into());
    if let Some(e) = &s.last_error {
        v.set("last_error", e.as_str().into());
    }
    v
}

fn usage_from_json(v: &Json) -> Option<UsageSample> {
    let f = |k: &str| v.get(k).and_then(Json::as_f64);
    let u = |k: &str| v.get(k).and_then(Json::as_u64);
    Some(UsageSample {
        usage: ResourceUsage {
            cpu_frac: f("cpu_frac")?,
            mem_used: u("mem_used")?,
            mem_total: u("mem_total")?,
            io_bytes_per_s: f("io_bytes_per_s").unwrap_or(0.0),
            gpu_frac: f("gpu_frac").unwrap_or(0.0),
            gpus_used: u("gpus_used").unwrap_or(0) as u32,
            gpus_total: u("gpus_total").unwrap_or(0) as u32,
        },
        collected_at: f("collected_at")?,
        consecutive_failures: u("consecutive_failures").unwrap_or(0) as u32,
        last_error: v.get("last_error").and_then(Json::as_str).map(str::to_string),
    })
}

fn lease_to_json(l: &ResourceLease) -> Json {
    let mut v = Json::obj();
    v.set("state", l.state.as_str().into())
        .set("misses", (l.misses as u64).into())
        .set("clean_sweeps", (l.clean_sweeps as u64).into())
        .set("since", l.since.into());
    if let Some(seen) = l.last_seen {
        v.set("last_seen", seen.into());
    }
    v
}

fn lease_from_json(v: &Json) -> Option<ResourceLease> {
    Some(ResourceLease {
        state: LeaseState::parse(v.get("state").and_then(Json::as_str)?)?,
        misses: v.get("misses").and_then(Json::as_u64).unwrap_or(0) as u32,
        clean_sweeps: v.get("clean_sweeps").and_then(Json::as_u64).unwrap_or(0) as u32,
        since: v.get("since").and_then(Json::as_f64).unwrap_or(0.0),
        last_seen: v.get("last_seen").and_then(Json::as_f64),
    })
}

/// Encode one exported loan for the steal response wire.
fn stolen_to_json(s: &StolenInstance) -> Json {
    let mut v = Json::obj();
    v.set("run", s.run.into())
        .set("app", s.app.as_str().into())
        .set("function", s.function.as_str().into())
        .set("instance", s.instance.into())
        .set("resource", (s.resource as u64).into())
        .set("class", s.class.as_str().into())
        .set("envelope", String::from_utf8_lossy(&s.envelope).into_owned().into())
        .set("attempt", s.attempt.into())
        .set("retried", s.retried.into());
    if let Some(r) = s.remaining_s {
        v.set("remaining_s", r.into());
    }
    v
}

fn stolen_from_json(v: &Json) -> anyhow::Result<StolenInstance> {
    let need_str = |k: &str| {
        v.get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("stolen instance: missing `{k}`"))
    };
    let need_u64 = |k: &str| {
        v.get(k)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("stolen instance: missing `{k}`"))
    };
    Ok(StolenInstance {
        run: need_u64("run")?,
        app: need_str("app")?.to_string(),
        function: need_str("function")?.to_string(),
        instance: need_u64("instance")? as usize,
        resource: need_u64("resource")? as ResourceId,
        class: need_str("class")?.parse::<Priority>().unwrap_or_default(),
        remaining_s: v.get("remaining_s").and_then(Json::as_f64),
        envelope: Bytes::from(need_str("envelope")?.to_string()),
        attempt: need_u64("attempt")?,
        retried: v.get("retried").and_then(Json::as_bool).unwrap_or(false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::VirtualClock;
    use crate::testbed::paper_testbed;

    fn fed_on(bed: &crate::testbed::TestBed, self_id: u32, members: u32) -> Arc<Federation> {
        Federation::enable(&bed.faas, FederationConfig::new(self_id, members))
            .expect("valid config")
    }

    #[test]
    fn ownership_is_consistent_and_total() {
        let clock: Arc<dyn crate::simnet::Clock> = Arc::new(VirtualClock::new());
        let bed = paper_testbed(Arc::clone(&clock));
        let fed = fed_on(&bed, 1, 4);
        // Every resource/app has exactly one owner, and the mapping only
        // depends on (name, members) — what every member computes.
        for rid in bed.faas.resource_ids() {
            assert_eq!(fed.owner_of_resource(rid), rid % 4);
        }
        let other = Federation::enable(&bed.faas, FederationConfig::new(3, 4)).unwrap();
        for app in ["videoanalysis", "federatedlearning", "popvideo7"] {
            assert_eq!(fed.owner_of_app(app), other.owner_of_app(app));
            assert!(fed.owner_of_app(app) < 4);
        }
        assert!(Federation::enable(&bed.faas, FederationConfig::new(4, 4)).is_err());
        assert!(
            Federation::enable(&bed.faas, FederationConfig::new(0, 2).peer(0, "x:1")).is_err(),
            "peer id == self refused"
        );
    }

    #[test]
    fn stolen_instance_wire_roundtrip() {
        let s = StolenInstance {
            run: 42,
            app: "video".into(),
            function: "extract".into(),
            instance: 3,
            resource: 7,
            class: Priority::Realtime,
            remaining_s: Some(1.25),
            envelope: Bytes::from(r#"{"name":"x","resource":7}"#),
            attempt: 99,
            retried: true,
        };
        let v = json::parse(&stolen_to_json(&s).to_string()).unwrap();
        let d = stolen_from_json(&v).unwrap();
        assert_eq!(
            (d.run, d.instance, d.resource, d.attempt, d.retried),
            (42, 3, 7, 99, true)
        );
        assert_eq!((d.app.as_str(), d.function.as_str()), ("video", "extract"));
        assert_eq!(d.class, Priority::Realtime);
        assert_eq!(d.remaining_s, Some(1.25));
        assert_eq!(&d.envelope[..], s.envelope.as_ref());
        assert!(stolen_from_json(&Json::obj()).is_err());
    }

    #[test]
    fn usage_and_lease_wire_roundtrip() {
        let sample = UsageSample {
            usage: ResourceUsage {
                cpu_frac: 0.25,
                mem_used: 1 << 20,
                mem_total: 1 << 30,
                io_bytes_per_s: 123.0,
                gpu_frac: 0.5,
                gpus_used: 1,
                gpus_total: 2,
            },
            collected_at: 9.5,
            consecutive_failures: 2,
            last_error: Some("scrape timed out".into()),
        };
        let v = json::parse(&usage_to_json(&sample).to_string()).unwrap();
        assert_eq!(usage_from_json(&v), Some(sample));
        let lease = ResourceLease {
            state: LeaseState::Recovering,
            misses: 0,
            clean_sweeps: 1,
            since: 4.0,
            last_seen: Some(4.0),
        };
        let v = json::parse(&lease_to_json(&lease).to_string()).unwrap();
        assert_eq!(lease_from_json(&v), Some(lease));
        assert!(lease_from_json(&Json::obj()).is_none());
    }

    #[test]
    fn merge_is_owner_authoritative_and_pessimistically_capped() {
        let clock = Arc::new(VirtualClock::new());
        let bed = paper_testbed(clock);
        let faas = &bed.faas;
        faas.refresh_monitor_snapshot();
        let ids = faas.resource_ids();
        let (victim, hearsay) = (ids[0], ids[1]);
        let dead = ResourceLease {
            state: LeaseState::Dead,
            misses: 3,
            clean_sweeps: 0,
            since: 1.0,
            last_seen: None,
        };
        // Owner-authoritative: the owner's Dead is adopted and drains.
        let auth: BTreeSet<ResourceId> = [victim].into_iter().collect();
        let mut leases = BTreeMap::new();
        leases.insert(victim, dead.clone());
        faas.merge_federated_view(&auth, &BTreeMap::new(), &leases);
        let snap = faas.monitor_snapshot();
        assert_eq!(snap.lease_of(victim).unwrap().state, LeaseState::Dead);
        // Non-owner hearsay about another resource caps at Suspect.
        let mut leases = BTreeMap::new();
        leases.insert(hearsay, dead.clone());
        faas.merge_federated_view(&BTreeSet::new(), &BTreeMap::new(), &leases);
        let snap = faas.monitor_snapshot();
        let l = snap.lease_of(hearsay).unwrap();
        assert_eq!(l.state, LeaseState::Suspect, "hearsay never kills");
        assert!(l.misses < faas.liveness_config().dead_after);
        // The owner re-admitting (schedulable state) restores the victim.
        let mut leases = BTreeMap::new();
        leases.insert(victim, ResourceLease::alive(2.0));
        faas.merge_federated_view(&auth, &BTreeMap::new(), &leases);
        let snap = faas.monitor_snapshot();
        assert_eq!(snap.lease_of(victim).unwrap().state, LeaseState::Alive);
        // Unknown resource ids in a push are ignored.
        let mut leases = BTreeMap::new();
        leases.insert(9999, dead);
        faas.merge_federated_view(&BTreeSet::new(), &BTreeMap::new(), &leases);
        assert!(faas.monitor_snapshot().lease_of(9999).is_none());
    }

    #[test]
    fn usage_only_merge_rekeys_the_decision_cache() {
        let clock = Arc::new(VirtualClock::new());
        let bed = paper_testbed(clock);
        let faas = &bed.faas;
        let epoch0 = faas.refresh_monitor_snapshot();
        let rid = faas.resource_ids()[0];
        // Plant a cached decision keyed to the current epoch.
        {
            let mut cache = faas.sched_cache.lock().unwrap();
            cache.epoch = epoch0;
            cache
                .map
                .insert(("app".into(), "f".into(), vec![], vec![]), vec![rid]);
        }
        // A fresher usage sample, same lease state: entries survive.
        let snap = faas.monitor_snapshot();
        let mut usage = BTreeMap::new();
        let mut newer = snap.usage_of(rid).unwrap().clone();
        newer.collected_at += 1.0;
        usage.insert(rid, newer);
        let mut leases = BTreeMap::new();
        leases.insert(rid, snap.lease_of(rid).unwrap().clone());
        let auth: BTreeSet<ResourceId> = [rid].into_iter().collect();
        let e1 = faas.merge_federated_view(&auth, &usage, &leases);
        assert!(e1 > epoch0);
        {
            let cache = faas.sched_cache.lock().unwrap();
            assert_eq!(cache.epoch, e1, "cache re-keyed to the merged epoch");
            assert_eq!(cache.map.len(), 1, "usage-only merge keeps entries");
        }
        // A lease-state change invalidates.
        let mut leases = BTreeMap::new();
        leases.insert(
            rid,
            ResourceLease {
                state: LeaseState::Suspect,
                misses: 1,
                clean_sweeps: 0,
                since: 2.0,
                last_seen: None,
            },
        );
        faas.merge_federated_view(&auth, &BTreeMap::new(), &leases);
        assert!(faas.sched_cache.lock().unwrap().map.is_empty(), "lease change invalidates");
    }

    #[test]
    fn gossip_receive_gates_by_sender_epoch() {
        let clock = Arc::new(VirtualClock::new());
        let bed = paper_testbed(clock);
        bed.faas.refresh_monitor_snapshot();
        let fed = fed_on(&bed, 0, 2);
        let mut push = Json::obj();
        push.set("from", 1u64.into())
            .set("epoch", 5u64.into())
            .set("owned", Json::Arr(vec![]))
            .set("usage", Json::obj())
            .set("leases", Json::obj());
        assert!(fed.receive_gossip(&push).unwrap().is_some(), "first push merges");
        assert!(fed.receive_gossip(&push).unwrap().is_none(), "replay skipped");
        let mut older = Json::obj();
        older
            .set("from", 1u64.into())
            .set("epoch", 4u64.into())
            .set("owned", Json::Arr(vec![]))
            .set("usage", Json::obj())
            .set("leases", Json::obj());
        assert!(fed.receive_gossip(&older).unwrap().is_none(), "stale push skipped");
        let (_, _, merged, skipped) = fed.gossip_counters();
        assert_eq!((merged, skipped), (1, 2));
        let mut own = Json::obj();
        own.set("from", 0u64.into()).set("epoch", 9u64.into());
        assert!(fed.receive_gossip(&own).is_err(), "own pushes refused");
    }

    #[test]
    fn export_view_restricts_to_owned_plus_adverse_evidence() {
        let clock = Arc::new(VirtualClock::new());
        let bed = paper_testbed(clock);
        let faas = &bed.faas;
        faas.refresh_monitor_snapshot();
        let fed = fed_on(&bed, 0, 2);
        let ids = faas.resource_ids();
        let not_owned: Vec<ResourceId> = ids.iter().copied().filter(|r| r % 2 != 0).collect();
        let view = fed.export_view().unwrap();
        let usage = view.get("usage").unwrap();
        for rid in &ids {
            let present = usage.get(&rid.to_string()).is_some();
            assert_eq!(present, rid % 2 == 0, "usage restricted to owned (rid {rid})");
        }
        // Mark a non-owned resource Suspect locally: it joins the lease
        // export as adverse evidence (the warning channel), with the
        // owned set unchanged.
        let hearsay = not_owned[0];
        faas.report_data_path_miss(hearsay);
        let view = fed.export_view().unwrap();
        assert!(view.get("leases").unwrap().get(&hearsay.to_string()).is_some());
        let owned = view.get("owned").unwrap().as_arr().unwrap();
        assert!(owned
            .iter()
            .all(|v| v.as_u64().unwrap() as ResourceId % 2 == 0));
    }

    #[test]
    fn loan_settling_handles_unknown_and_duplicate_reports() {
        let clock = Arc::new(VirtualClock::new());
        let bed = paper_testbed(clock);
        let fed = fed_on(&bed, 0, 2);
        // No loan outstanding: the report is dropped, not an error.
        let mut report = Json::obj();
        report
            .set("run", 7u64.into())
            .set("function", "extract".into())
            .set("instance", 0usize.into())
            .set("ok", true.into())
            .set("resource", 0u64.into())
            .set("latency", 0.01.into())
            .set("outputs", Json::Arr(vec![]));
        assert_eq!(fed.receive_complete(&report).unwrap(), false);
        // Nothing queued: stealing exports nothing, reclaim is a no-op.
        assert_eq!(fed.serve_steal(8).unwrap().get("instances").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(fed.reclaim(), 0);
    }
}
