//! Storage virtualization (§3.3.1).
//!
//! "EdgeFaaS virtualizes all the resources' storage and provide a unified
//! interface for users to access different storage resources." Users (and
//! functions) see only EdgeFaaS bucket names and opaque object URLs of the
//! form `application_name/bucket_name/resource_ID/object_name`; the
//! coordinator routes each verb to the owning resource's MinIO stand-in via
//! the bucket map.

use crate::objstore::store::valid_bucket_name;

use super::placement;
use super::resource::{EdgeFaaS, ResourceId};
use crate::util::bytes::Bytes;
use crate::util::json::Json;

/// A parsed EdgeFaaS object URL:
/// `application_name/bucket_name/resource_ID/object_name`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ObjectUrl {
    pub application: String,
    pub bucket: String,
    pub resource: ResourceId,
    pub object: String,
}

impl ObjectUrl {
    pub fn parse(url: &str) -> anyhow::Result<ObjectUrl> {
        let parts: Vec<&str> = url.splitn(4, '/').collect();
        if parts.len() != 4 {
            anyhow::bail!("bad object url `{url}` (want app/bucket/resource/object)");
        }
        Ok(ObjectUrl {
            application: parts[0].to_string(),
            bucket: parts[1].to_string(),
            resource: parts[2]
                .parse()
                .map_err(|_| anyhow::anyhow!("bad resource id in url `{url}`"))?,
            object: parts[3].to_string(),
        })
    }

}

impl std::fmt::Display for ObjectUrl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}/{}/{}", self.application, self.bucket, self.resource, self.object)
    }
}

impl EdgeFaaS {
    /// The EdgeFaaS bucket name: "ApplicationName + BucketName" namespacing
    /// keeps different applications' datasets isolated.
    pub fn qualified_bucket(app: &str, bucket: &str) -> String {
        format!("{app}.{bucket}")
    }

    /// Create an EdgeFaaS storage bucket. `locality` pins the backing
    /// resource (the data-placement hint — e.g. "store where generated");
    /// without it the placement policy picks a resource (§3.3.2).
    pub fn create_bucket(
        &self,
        app: &str,
        bucket: &str,
        locality: Option<ResourceId>,
    ) -> anyhow::Result<()> {
        if !valid_bucket_name(bucket) {
            anyhow::bail!("bucket name `{bucket}` violates the S3 naming rules");
        }
        let qb = Self::qualified_bucket(app, bucket);
        if self.buckets.read().unwrap().contains_key(&qb) {
            anyhow::bail!("bucket `{bucket}` already exists for `{app}`");
        }
        let rid = match locality {
            Some(id) => id,
            None => placement::pick_bucket_resource(self)?,
        };
        let reg = self.resource(rid)?;
        reg.handle.make_bucket(&qb)?;
        // bucket map: EdgeFaaS BucketName -> resourceID, backed up.
        self.kv.put("bucket_map", &qb, Json::Num(rid as f64))?;
        self.buckets.write().unwrap().insert(qb, rid);
        // application_bucket mapping tracks original user names.
        let mut ab = self.app_buckets.write().unwrap();
        let list = ab.entry(app.to_string()).or_default();
        list.push(bucket.to_string());
        let rec = Json::Arr(list.iter().map(|b| Json::Str(b.clone())).collect());
        self.kv.put("application_bucket", app, rec)?;
        Ok(())
    }

    /// Delete an EdgeFaaS bucket (must be empty, mirroring MinIO).
    pub fn delete_bucket(&self, app: &str, bucket: &str) -> anyhow::Result<()> {
        let qb = Self::qualified_bucket(app, bucket);
        let rid = self.bucket_resource(app, bucket)?;
        let reg = self.resource(rid)?;
        reg.handle.remove_bucket(&qb)?;
        self.buckets.write().unwrap().remove(&qb);
        self.kv.delete("bucket_map", &qb)?;
        let mut ab = self.app_buckets.write().unwrap();
        if let Some(list) = ab.get_mut(app) {
            list.retain(|b| b != bucket);
            let rec = Json::Arr(list.iter().map(|b| Json::Str(b.clone())).collect());
            self.kv.put("application_bucket", app, rec)?;
        }
        Ok(())
    }

    /// All buckets created for an application (original user names).
    pub fn list_buckets(&self, app: &str) -> Vec<String> {
        self.app_buckets.read().unwrap().get(app).cloned().unwrap_or_default()
    }

    /// Which resource backs a bucket.
    pub fn bucket_resource(&self, app: &str, bucket: &str) -> anyhow::Result<ResourceId> {
        let qb = Self::qualified_bucket(app, bucket);
        self.buckets
            .read()
            .unwrap()
            .get(&qb)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("no bucket `{bucket}` for `{app}`"))
    }

    /// Add an object; returns its URL ("Each successfully uploaded object is
    /// given a url to user where user can use to access the data").
    ///
    /// The borrowed payload is copied into a shared buffer once; callers
    /// that already hold a [`Bytes`] should use
    /// [`Self::put_object_bytes`] for a fully zero-copy store.
    pub fn put_object(
        &self,
        app: &str,
        bucket: &str,
        object: &str,
        data: &[u8],
    ) -> anyhow::Result<ObjectUrl> {
        self.put_object_bytes(app, bucket, object, Bytes::copy_from(data))
    }

    /// Zero-copy variant of [`Self::put_object`]: the shared buffer is moved
    /// into the owning resource's store (a refcount transfer against a
    /// local backend).
    pub fn put_object_bytes(
        &self,
        app: &str,
        bucket: &str,
        object: &str,
        data: Bytes,
    ) -> anyhow::Result<ObjectUrl> {
        if object.is_empty() {
            anyhow::bail!("empty object name");
        }
        let rid = self.bucket_resource(app, bucket)?;
        let reg = self.resource(rid)?;
        let qb = Self::qualified_bucket(app, bucket);
        reg.handle.put_object(&qb, object, data)?;
        Ok(ObjectUrl {
            application: app.to_string(),
            bucket: bucket.to_string(),
            resource: rid,
            object: object.to_string(),
        })
    }

    /// Retrieve an object by URL. Returns shared [`Bytes`] — against a local
    /// backend this is a refcount bump on the stored buffer, not a copy.
    pub fn get_object(&self, url: &ObjectUrl) -> anyhow::Result<Bytes> {
        let reg = self.resource(url.resource)?;
        let qb = Self::qualified_bucket(&url.application, &url.bucket);
        reg.handle.get_object(&qb, &url.object)
    }

    /// Retrieve an object by URL string.
    pub fn get_object_url(&self, url: &str) -> anyhow::Result<Bytes> {
        self.get_object(&ObjectUrl::parse(url)?)
    }

    /// Delete an object.
    pub fn delete_object(&self, app: &str, bucket: &str, object: &str) -> anyhow::Result<()> {
        let rid = self.bucket_resource(app, bucket)?;
        let reg = self.resource(rid)?;
        let qb = Self::qualified_bucket(app, bucket);
        reg.handle.remove_object(&qb, object)
    }

    /// List objects in a bucket.
    pub fn list_objects(&self, app: &str, bucket: &str) -> anyhow::Result<Vec<String>> {
        let rid = self.bucket_resource(app, bucket)?;
        let reg = self.resource(rid)?;
        let qb = Self::qualified_bucket(app, bucket);
        reg.handle.list_objects(&qb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resource::testkit::paper_testbed;
    use crate::simnet::RealClock;
    use std::sync::Arc;

    #[test]
    fn object_url_roundtrip() {
        let u = ObjectUrl::parse("videopipeline/frames/3/gop/0.zip").unwrap();
        assert_eq!(u.application, "videopipeline");
        assert_eq!(u.bucket, "frames");
        assert_eq!(u.resource, 3);
        assert_eq!(u.object, "gop/0.zip", "object names may contain slashes");
        assert_eq!(u.to_string(), "videopipeline/frames/3/gop/0.zip");
        assert!(ObjectUrl::parse("too/short/2").is_err());
        assert!(ObjectUrl::parse("a/b/notanid/o").is_err());
    }

    #[test]
    fn bucket_lifecycle_with_locality() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        let app = "videopipeline";
        b.faas.create_bucket(app, "frames", Some(b.iot[2])).unwrap();
        assert_eq!(b.faas.bucket_resource(app, "frames").unwrap(), b.iot[2]);
        assert_eq!(b.faas.list_buckets(app), vec!["frames"]);
        // Data actually lives on the chosen resource.
        let url = b.faas.put_object(app, "frames", "f0.bin", b"framedata").unwrap();
        assert_eq!(url.resource, b.iot[2]);
        assert_eq!(b.faas.get_object(&url).unwrap(), &b"framedata"[..]);
        let reg = b.faas.resource(b.iot[2]).unwrap();
        assert_eq!(reg.handle.stored_bytes().unwrap(), 9);
        // Cleanup ordering enforced.
        assert!(b.faas.delete_bucket(app, "frames").is_err(), "bucket not empty");
        b.faas.delete_object(app, "frames", "f0.bin").unwrap();
        b.faas.delete_bucket(app, "frames").unwrap();
        assert!(b.faas.list_buckets(app).is_empty());
    }

    #[test]
    fn namespaces_isolate_applications() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        b.faas.create_bucket("app1", "data", Some(b.cloud)).unwrap();
        b.faas.create_bucket("app2", "data", Some(b.cloud)).unwrap();
        b.faas.put_object("app1", "data", "o", b"one").unwrap();
        b.faas.put_object("app2", "data", "o", b"two").unwrap();
        let u1 = ObjectUrl::parse(&format!("app1/data/{}/o", b.cloud)).unwrap();
        let u2 = ObjectUrl::parse(&format!("app2/data/{}/o", b.cloud)).unwrap();
        assert_eq!(b.faas.get_object(&u1).unwrap(), &b"one"[..]);
        assert_eq!(b.faas.get_object(&u2).unwrap(), &b"two"[..]);
    }

    #[test]
    fn duplicate_and_invalid_buckets_rejected() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        b.faas.create_bucket("app", "data", Some(b.cloud)).unwrap();
        assert!(b.faas.create_bucket("app", "data", Some(b.cloud)).is_err());
        assert!(b.faas.create_bucket("app", "BAD_NAME", Some(b.cloud)).is_err());
        assert!(b.faas.create_bucket("app", "xy", Some(b.cloud)).is_err(), "too short");
    }

    #[test]
    fn mappings_are_backed_up() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        b.faas.create_bucket("fl", "models", Some(b.edges[0])).unwrap();
        assert_eq!(
            b.faas.kv.get("bucket_map", "fl.models").unwrap().as_u64(),
            Some(b.edges[0] as u64)
        );
        let rec = b.faas.kv.get("application_bucket", "fl").unwrap();
        assert_eq!(rec.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn default_placement_picks_some_resource() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        b.faas.create_bucket("app", "anywhere", None).unwrap();
        let rid = b.faas.bucket_resource("app", "anywhere").unwrap();
        assert!(b.faas.resource(rid).is_ok());
    }

    #[test]
    fn missing_objects_error() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        b.faas.create_bucket("app", "data", Some(b.cloud)).unwrap();
        let u = ObjectUrl::parse(&format!("app/data/{}/nope", b.cloud)).unwrap();
        assert!(b.faas.get_object(&u).is_err());
        assert!(b.faas.bucket_resource("app", "ghost").is_err());
    }
}
