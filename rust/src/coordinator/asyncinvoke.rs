//! Asynchronous invocation + load-driven rescheduling — the asynchronous
//! front-end over the event-driven execution engine.
//!
//! §3.2.1: "A function can be invoked synchronously (and wait for the
//! response), or asynchronously. To invoke a function asynchronously, set
//! Sync to False." — [`EdgeFaaS::invoke_async`] submits a job to the
//! engine's shared worker pool ([`EdgeFaaS::spawn_job`]) and returns an
//! invocation id immediately; results are polled (or awaited) through the
//! tracker, the OpenFaaS async-function pattern. Because the job runs on
//! the same pool as workflow instances, async invocations are subject to
//! the same worker cap and interleave fairly with in-flight workflow runs.
//! Jobs ride the engine's sharded dispatch queues like instances do
//! (spread across shards by submission sequence), so a burst of async
//! invocations does not serialize against workflow dispatch on any global
//! lock.
//!
//! §3.1.2 + the NanoLambda comparison (§6: NanoLambda "does not follow the
//! dynamic changes of system loads ... to reschedule functions" — implying
//! EdgeFaaS does): [`EdgeFaaS::reschedule_function`] re-runs the two-phase
//! scheduler against *current* monitoring data (it bypasses the placement
//! decision cache) and migrates deployments whose placement changed.
//!
//! [`EdgeFaaS::enable_auto_reschedule`] closes the loop automatically: an
//! `on_engine_event` subscriber keeps a per-`(function, resource)` latency
//! EWMA from `NodeCompleted` events and reacts to `DeadlineMissed`,
//! migrating a hot function through `reschedule_function` — rate-limited
//! per function, decided off the monitoring snapshot, and never touching
//! an executing instance (migration is deployment-level make-before-break:
//! future firings go to the new placement; in-flight invocations complete
//! where they started).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::bytes::Bytes;
use crate::util::json::Json;

use super::engine::{EngineEvent, Priority, QoS};
use super::functions::FunctionPackage;
use super::resource::{EdgeFaaS, ResourceId};
use super::scheduler::FunctionCreation;

/// Handle for one asynchronous invocation.
pub type InvocationId = u64;

/// Status of an async invocation. Outputs are shared [`Bytes`]: polling or
/// cloning a completed status bumps refcounts instead of copying payloads.
#[derive(Debug, Clone)]
pub enum AsyncStatus {
    Pending,
    Done(Vec<(ResourceId, Bytes, f64)>),
    Failed(String),
}

/// Tracker for in-flight async invocations.
#[derive(Default)]
pub struct AsyncTracker {
    next: AtomicU64,
    state: Mutex<HashMap<InvocationId, AsyncStatus>>,
    cv: Condvar,
}

impl AsyncTracker {
    pub fn new() -> Arc<AsyncTracker> {
        Arc::new(AsyncTracker::default())
    }

    fn begin(&self) -> InvocationId {
        let id = self.next.fetch_add(1, Ordering::SeqCst);
        self.state.lock().unwrap().insert(id, AsyncStatus::Pending);
        id
    }

    fn finish(&self, id: InvocationId, status: AsyncStatus) {
        self.state.lock().unwrap().insert(id, status);
        self.cv.notify_all();
    }

    /// Non-blocking poll.
    pub fn poll(&self, id: InvocationId) -> Option<AsyncStatus> {
        self.state.lock().unwrap().get(&id).cloned()
    }

    /// Block until the invocation completes (or `timeout_s` elapses).
    pub fn wait(&self, id: InvocationId, timeout_s: f64) -> anyhow::Result<AsyncStatus> {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs_f64(timeout_s);
        let mut guard = self.state.lock().unwrap();
        loop {
            match guard.get(&id) {
                None => anyhow::bail!("unknown invocation {id}"),
                Some(AsyncStatus::Pending) => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        anyhow::bail!("invocation {id} timed out");
                    }
                    let (g, _) = self.cv.wait_timeout(guard, deadline - now).unwrap();
                    guard = g;
                }
                Some(done) => return Ok(done.clone()),
            }
        }
    }

    /// Drop a completed invocation's record.
    pub fn forget(&self, id: InvocationId) {
        self.state.lock().unwrap().remove(&id);
    }
}

impl EdgeFaaS {
    /// Invoke() with Sync=False: submit a job to the execution engine's
    /// worker pool, return the invocation id immediately. Results land in
    /// `tracker`. Submits under the default [`QoS`] (`Interactive`); see
    /// [`Self::invoke_async_qos`].
    pub fn invoke_async(
        self: &Arc<Self>,
        tracker: &Arc<AsyncTracker>,
        app: &str,
        function: &str,
        payload: &Json,
        invoke_one: bool,
    ) -> InvocationId {
        self.invoke_async_qos(tracker, app, function, payload, invoke_one, QoS::default())
    }

    /// [`Self::invoke_async`] under an explicit [`QoS`]: the class orders
    /// the invocation's job against every queued workflow instance and job
    /// (a `Batch` async invocation yields to `Realtime` workflow work), and
    /// a deadline is an EDF ordering hint — single invocations are opaque
    /// jobs, so they are never deadline-cancelled.
    pub fn invoke_async_qos(
        self: &Arc<Self>,
        tracker: &Arc<AsyncTracker>,
        app: &str,
        function: &str,
        payload: &Json,
        invoke_one: bool,
        qos: QoS,
    ) -> InvocationId {
        let id = tracker.begin();
        let tracker = Arc::clone(tracker);
        let (app, function, payload) = (app.to_string(), function.to_string(), payload.clone());
        self.spawn_job_qos(qos, move |faas| {
            let status = match faas.invoke(&app, &function, &payload, invoke_one) {
                Ok(results) => AsyncStatus::Done(results),
                Err(e) => AsyncStatus::Failed(e.to_string()),
            };
            tracker.finish(id, status);
        });
        id
    }

    /// Re-run two-phase scheduling for a deployed function against current
    /// monitoring data; if the placement changed, deploy on the new
    /// resources and remove from the abandoned ones. Returns
    /// `(old, new)` placements.
    ///
    /// Bypasses the placement decision cache — an explicit reschedule must
    /// observe current load, not a memoized decision — and drops any
    /// cached entries afterwards so later `schedule_function` calls cannot
    /// resurrect the pre-migration placement.
    pub fn reschedule_function(
        &self,
        app: &str,
        function: &str,
        package: &FunctionPackage,
        data_locations: Vec<ResourceId>,
    ) -> anyhow::Result<(Vec<ResourceId>, Vec<ResourceId>)> {
        let application = self.app(app)?;
        let cfg = application
            .config
            .function(function)
            .ok_or_else(|| anyhow::anyhow!("no function `{function}` in `{app}`"))?
            .clone();
        let old = self.candidates_of(app, function)?;
        // Dependency placements as currently recorded.
        let mut dep_locations = Vec::new();
        for d in &cfg.dependencies {
            dep_locations.extend(self.candidates_of(app, d).unwrap_or_default());
        }
        let request = FunctionCreation {
            app: app.to_string(),
            function: cfg,
            data_locations,
            dep_locations,
        };
        let new = self.schedule_function_uncached(&request)?;
        if new == old {
            return Ok((old.clone(), new));
        }
        self.invalidate_schedule_cache();
        let qname = Self::qualified(app, function);
        // Deploy on newly-chosen resources first (make-before-break), then
        // remove from the abandoned ones.
        let labels =
            vec![("app".to_string(), app.to_string()), ("fn".to_string(), function.to_string())];
        for &rid in new.iter().filter(|r| !old.contains(r)) {
            let reg = self.resource(rid)?;
            reg.handle.deploy(
                &qname,
                &package.code,
                request_memory(self, app, function)?,
                0,
                &labels,
            )?;
        }
        for &rid in old.iter().filter(|r| !new.contains(r)) {
            if let Ok(reg) = self.resource(rid) {
                let _ = reg.handle.remove(&qname);
            }
        }
        log::info!("rescheduled {qname}: {old:?} -> {new:?}");
        Ok((old, new))
    }
}

pub(super) fn request_memory(faas: &EdgeFaaS, app: &str, function: &str) -> anyhow::Result<u64> {
    Ok(faas
        .app(app)?
        .config
        .function(function)
        .map(|f| f.requirements.memory)
        .unwrap_or(128 << 20))
}

/// Configuration of the automatic reschedule policy
/// ([`EdgeFaaS::enable_auto_reschedule`]).
#[derive(Debug, Clone, Copy)]
pub struct AutoRescheduleConfig {
    /// EWMA smoothing factor in (0, 1]: weight of the newest sample.
    pub alpha: f64,
    /// Migrate a function once any of its placements' latency EWMA
    /// exceeds this (seconds). `INFINITY` (the default) disables the
    /// latency trigger — the policy then reacts to `DeadlineMissed` only.
    pub latency_threshold_s: f64,
    /// Minimum coordinator-clock seconds between two migration attempts of
    /// the same function (the rate limit).
    pub min_interval_s: f64,
    /// Backoff after a migration that did not help: if, at the next
    /// trigger, the function's hotness has not dropped below
    /// `improvement_factor` × its pre-migration value, further attempts
    /// are refused until `cooldown_s` seconds have passed since that
    /// migration. Stops a function the reschedule *cannot* help (e.g. the
    /// only candidate is the hot one) from being migrated in a loop.
    pub cooldown_s: f64,
    /// The "it helped" bar for lifting the cooldown early, as a fraction
    /// of the pre-migration hotness (0.9 = at least 10% better).
    pub improvement_factor: f64,
    /// Half-life (seconds) of a placement's latency EWMA when no new
    /// samples arrive. An idle function's hotness decays instead of
    /// holding its last value forever, so a deadline miss hours later
    /// does not migrate a long-cold former hot spot.
    pub idle_half_life_s: f64,
    /// Sliding-window migration budget per *application*: at most this
    /// many migration attempts (across all of the app's functions) within
    /// any `migration_window_s` span. The per-function gates above stop
    /// one function from thrashing; this stops an app whose functions
    /// take turns being hot from churning its deployments continuously.
    /// `usize::MAX` (the default) disables the budget.
    pub max_migrations_per_app: usize,
    /// Length (seconds) of the `max_migrations_per_app` sliding window.
    pub migration_window_s: f64,
}

impl Default for AutoRescheduleConfig {
    fn default() -> Self {
        AutoRescheduleConfig {
            alpha: 0.3,
            latency_threshold_s: f64::INFINITY,
            min_interval_s: 10.0,
            cooldown_s: 60.0,
            improvement_factor: 0.9,
            idle_half_life_s: 300.0,
            max_migrations_per_app: usize::MAX,
            migration_window_s: 60.0,
        }
    }
}

/// Handle to a running auto-reschedule policy: observability counters for
/// operators and tests. The policy itself runs inside an
/// `on_engine_event` subscription.
pub struct AutoRescheduler {
    cfg: AutoRescheduleConfig,
    /// Latency EWMA per (qualified function, resource): `(value,
    /// last_sample_at)`. The value decays with `idle_half_life_s` when
    /// read, so idle placements cool off.
    ewma: Mutex<HashMap<(String, ResourceId), (f64, f64)>>,
    /// Last migration per qualified function: `(at, pre_migration
    /// hotness)` — the cooldown's evidence that the move helped (or not).
    outcomes: Mutex<HashMap<String, (f64, f64)>>,
    /// Last migration-attempt clock time per qualified function.
    last_attempt: Mutex<HashMap<String, f64>>,
    /// Admitted-attempt clock times per application, pruned to the
    /// sliding `migration_window_s` — the `max_migrations_per_app`
    /// budget's evidence.
    app_attempts: Mutex<HashMap<String, Vec<f64>>>,
    /// Functions with a migration job currently queued/running.
    inflight: Mutex<HashSet<String>>,
    /// Migration attempts dispatched (rate limit and in-flight gate
    /// passed).
    attempts: AtomicU64,
    /// Attempts whose reschedule actually changed the placement.
    moved: AtomicU64,
}

impl AutoRescheduler {
    /// Migration attempts dispatched so far.
    pub fn attempts(&self) -> u64 {
        self.attempts.load(Ordering::SeqCst)
    }

    /// Attempts that changed the placement.
    pub fn moved(&self) -> u64 {
        self.moved.load(Ordering::SeqCst)
    }

    /// Latency EWMA for one placement as of its last sample (undecayed),
    /// if any samples arrived.
    pub fn ewma(&self, app: &str, function: &str, resource: ResourceId) -> Option<f64> {
        self.ewma
            .lock()
            .unwrap()
            .get(&(EdgeFaaS::qualified(app, function), resource))
            .map(|&(v, _)| v)
    }

    /// A stored EWMA value cooled down to `now`: halves every
    /// `idle_half_life_s` seconds without a sample.
    fn decayed(&self, value: f64, last_at: f64, now: f64) -> f64 {
        if self.cfg.idle_half_life_s <= 0.0 {
            return value;
        }
        let dt = (now - last_at).max(0.0);
        value * 0.5f64.powf(dt / self.cfg.idle_half_life_s)
    }

    /// Fold one latency sample into the EWMA; returns the new value. The
    /// stored value is first decayed to `now`, so a placement that sat
    /// idle re-learns its hotness from near-zero rather than from stale
    /// history.
    fn observe(&self, qname: &str, resource: ResourceId, latency: f64, now: f64) -> f64 {
        let mut map = self.ewma.lock().unwrap();
        let e = map.entry((qname.to_string(), resource)).or_insert((latency, now));
        let cooled = self.decayed(e.0, e.1, now);
        *e = (self.cfg.alpha * latency + (1.0 - self.cfg.alpha) * cooled, now);
        e.0
    }

    /// The hottest placement of `qname` across resources, decayed to
    /// `now`. `None` when no samples arrived yet.
    fn max_effective(&self, qname: &str, now: f64) -> Option<f64> {
        let map = self.ewma.lock().unwrap();
        map.iter()
            .filter(|((q, _), _)| q.as_str() == qname)
            .map(|(_, &(v, at))| self.decayed(v, at, now))
            .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))))
    }

    /// The function of `app` with the highest decayed EWMA (the "hot"
    /// migration candidate when a deadline miss names only the app).
    fn hottest_of_app(&self, app: &str, now: f64) -> Option<String> {
        let prefix = format!("{app}.");
        let map = self.ewma.lock().unwrap();
        map.iter()
            .filter(|((q, _), _)| q.starts_with(&prefix))
            .map(|((q, _), &(v, at))| (q, self.decayed(v, at, now)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(q, _)| q.clone())
    }

    /// Rate-limit + in-flight + cooldown gate; returns true when a
    /// migration job should be dispatched for `qname` (and records the
    /// attempt time and the pre-migration hotness).
    ///
    /// The in-flight lock is held across check *and* insert: engine events
    /// fire on concurrent worker threads, and a check-then-reacquire gap
    /// would let two events both dispatch a migration for one function.
    /// (Lock order inflight → outcomes → last_attempt → app_attempts;
    /// this is the only place they nest. The ewma lock is taken *before*
    /// inflight and released first — `max_effective` never nests inside
    /// the others.)
    fn admit_attempt(&self, qname: &str, now: f64) -> bool {
        let hotness = self.max_effective(qname, now);
        let mut inflight = self.inflight.lock().unwrap();
        if inflight.contains(qname) {
            return false;
        }
        let mut outcomes = self.outcomes.lock().unwrap();
        if let Some(&(at, pre)) = outcomes.get(qname) {
            // The last migration only counts as "helped" once the
            // function's hotness dropped below improvement_factor × its
            // pre-migration value; until then (or until the cooldown
            // lapses) re-migrating would just shuffle the same load.
            let unimproved =
                hotness.is_some_and(|h| h > self.cfg.improvement_factor * pre);
            if now - at < self.cfg.cooldown_s && unimproved {
                return false;
            }
        }
        let mut last = self.last_attempt.lock().unwrap();
        if let Some(t) = last.get(qname) {
            if now - t < self.cfg.min_interval_s {
                return false;
            }
        }
        // Per-app sliding-window budget, checked last so a refusal leaves
        // every earlier gate's state untouched (a budget-refused attempt
        // must not reset the rate limit or enter the cooldown).
        let app = qname.split_once('.').map(|(a, _)| a).unwrap_or(qname);
        let mut per_app = self.app_attempts.lock().unwrap();
        let window = per_app.entry(app.to_string()).or_default();
        window.retain(|&t| now - t < self.cfg.migration_window_s);
        if window.len() >= self.cfg.max_migrations_per_app {
            return false;
        }
        window.push(now);
        drop(per_app);
        last.insert(qname.to_string(), now);
        inflight.insert(qname.to_string());
        // No samples yet → pre-hotness ∞, so the next trigger inside the
        // cooldown always passes the improvement check.
        outcomes.insert(qname.to_string(), (now, hotness.unwrap_or(f64::INFINITY)));
        true
    }
}

impl EdgeFaaS {
    /// Wire `reschedule_function` to engine events: subscribe the
    /// auto-reschedule policy (the ROADMAP's "auto-policy should watch
    /// node latencies and migrate hot functions").
    ///
    /// * **Watch.** Every [`EngineEvent::NodeCompleted`] folds its
    ///   per-placement latencies into a `(function, resource)` EWMA.
    /// * **React.** When an EWMA crosses `latency_threshold_s`, or an
    ///   [`EngineEvent::DeadlineMissed`] fires (the policy picks the
    ///   missed app's hottest function by EWMA), a migration is attempted.
    /// * **Migrate safely.** Attempts are rate-limited per function
    ///   (`min_interval_s`) and serialized (at most one in flight per
    ///   function); the migration itself runs as a `Batch`-class engine
    ///   job calling [`Self::reschedule_function`] with the recorded
    ///   deployment package and data anchors — placement is decided off
    ///   the monitoring snapshot, deployment is make-before-break, and no
    ///   executing instance is ever cancelled (only future firings move).
    ///
    /// Returns a handle with attempt/moved counters. Functions without a
    /// recorded package (never deployed through `deploy_function`) are
    /// skipped.
    pub fn enable_auto_reschedule(
        self: &Arc<Self>,
        cfg: AutoRescheduleConfig,
    ) -> Arc<AutoRescheduler> {
        let policy = Arc::new(AutoRescheduler {
            cfg,
            ewma: Mutex::new(HashMap::new()),
            outcomes: Mutex::new(HashMap::new()),
            last_attempt: Mutex::new(HashMap::new()),
            app_attempts: Mutex::new(HashMap::new()),
            inflight: Mutex::new(HashSet::new()),
            attempts: AtomicU64::new(0),
            moved: AtomicU64::new(0),
        });
        let subscriber = Arc::clone(&policy);
        // The callback receives `&EdgeFaaS`; dispatching the migration job
        // needs an owned `Arc`, captured weakly so the subscription does
        // not keep the coordinator alive through its own callback list.
        let weak = Arc::downgrade(self);
        self.on_engine_event(move |faas, ev| {
            let hot: Option<String> = match ev {
                EngineEvent::NodeCompleted { app, function, instance_latencies, .. } => {
                    let qname = EdgeFaaS::qualified(app, function);
                    let now = faas.clock().now();
                    let mut worst = f64::NEG_INFINITY;
                    for &(rid, lat) in instance_latencies {
                        worst = worst.max(subscriber.observe(&qname, rid, lat, now));
                    }
                    (worst > subscriber.cfg.latency_threshold_s).then_some(qname)
                }
                EngineEvent::DeadlineMissed { app, .. } => {
                    subscriber.hottest_of_app(app, faas.clock().now())
                }
                _ => None,
            };
            let Some(qname) = hot else { return };
            let Some((app, function)) = qname.split_once('.') else { return };
            let Some(package) = faas.deployed_package(app, function) else { return };
            let Some(strong) = weak.upgrade() else { return };
            if !subscriber.admit_attempt(&qname, faas.clock().now()) {
                return;
            }
            subscriber.attempts.fetch_add(1, Ordering::SeqCst);
            let anchors = faas.data_anchor(app, function);
            let (app, function) = (app.to_string(), function.to_string());
            let policy = Arc::clone(&subscriber);
            // The migration runs as a Batch-class engine job — it must
            // never delay the latency-critical work it exists to help, and
            // it must not re-enter the coordinator from inside the event
            // emission path.
            strong.spawn_job_qos(QoS::class(Priority::Batch), move |faas| {
                match faas.reschedule_function(&app, &function, &package, anchors) {
                    Ok((old, new)) => {
                        if new != old {
                            policy.moved.fetch_add(1, Ordering::SeqCst);
                            log::info!(
                                "auto-reschedule migrated {qname}: {old:?} -> {new:?}"
                            );
                        }
                    }
                    Err(e) => log::warn!("auto-reschedule of {qname} failed: {e}"),
                }
                policy.inflight.lock().unwrap().remove(&qname);
            });
        });
        policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::appconfig::federated_learning_yaml;
    use crate::simnet::RealClock;
    use crate::testbed::paper_testbed;

    fn configured() -> crate::testbed::TestBed {
        let bed = paper_testbed(Arc::new(RealClock::new()));
        let mut data = HashMap::new();
        data.insert("train".to_string(), bed.iot.clone());
        bed.faas.configure_application(federated_learning_yaml(), &data).unwrap();
        bed
    }

    #[test]
    fn async_invoke_completes_and_is_pollable() {
        let bed = configured();
        bed.executor.register("img/slow", |p: &[u8]| {
            std::thread::sleep(std::time::Duration::from_millis(50));
            Ok(p.to_vec())
        });
        bed.faas
            .deploy_function(
                "federatedlearning",
                "secondaggregation",
                &FunctionPackage { code: "img/slow".into() },
            )
            .unwrap();
        let tracker = AsyncTracker::new();
        let id = bed.faas.invoke_async(
            &tracker,
            "federatedlearning",
            "secondaggregation",
            &Json::obj(),
            true,
        );
        // Immediately pending (the handler sleeps 50 ms).
        assert!(matches!(tracker.poll(id), Some(AsyncStatus::Pending)));
        let status = tracker.wait(id, 5.0).unwrap();
        match status {
            AsyncStatus::Done(results) => assert_eq!(results.len(), 1),
            other => panic!("unexpected status {other:?}"),
        }
        tracker.forget(id);
        assert!(tracker.poll(id).is_none());
    }

    #[test]
    fn async_failure_is_reported() {
        let bed = configured();
        bed.executor.register("img/fail", |_: &[u8]| anyhow::bail!("boom"));
        bed.faas
            .deploy_function(
                "federatedlearning",
                "secondaggregation",
                &FunctionPackage { code: "img/fail".into() },
            )
            .unwrap();
        let tracker = AsyncTracker::new();
        let id = bed.faas.invoke_async(
            &tracker,
            "federatedlearning",
            "secondaggregation",
            &Json::obj(),
            true,
        );
        match tracker.wait(id, 5.0).unwrap() {
            AsyncStatus::Failed(msg) => assert!(msg.contains("boom"), "{msg}"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn wait_times_out_and_unknown_id_errors() {
        let tracker = AsyncTracker::new();
        assert!(tracker.wait(999, 0.05).is_err(), "unknown id");
        let id = tracker.begin();
        assert!(tracker.wait(id, 0.05).is_err(), "times out while pending");
    }

    #[test]
    fn reschedule_is_stable_without_load_change() {
        let bed = configured();
        bed.executor.register("img/noop", |_: &[u8]| Ok(vec![]));
        let pkg = FunctionPackage { code: "img/noop".into() };
        bed.faas.deploy_function("federatedlearning", "train", &pkg).unwrap();
        let (old, new) = bed
            .faas
            .reschedule_function("federatedlearning", "train", &pkg, bed.iot.clone())
            .unwrap();
        assert_eq!(old, new, "same load, same placement");
    }

    /// A single-placement edge function (`mono.f`, anchored at iot[0])
    /// with a workflow-shaped handler, ready for auto-reschedule tests.
    fn mono_bed() -> crate::testbed::TestBed {
        let bed = paper_testbed(Arc::new(RealClock::new()));
        bed.executor.register("img/ok", |_: &[u8]| Ok(br#"{"outputs":[]}"#.to_vec()));
        let yaml = "\
application: mono
entrypoint: f
dag:
  - name: f
    requirements:
      memory: 1024MB
    affinity:
      nodetype: edge
      affinitytype: data
    reduce: 1
";
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![bed.iot[0]]);
        bed.faas.configure_application(yaml, &data).unwrap();
        bed.faas
            .deploy_function("mono", "f", &FunctionPackage { code: "img/ok".into() })
            .unwrap();
        bed
    }

    #[test]
    fn auto_reschedule_reacts_to_deadline_miss() {
        let bed = mono_bed();
        let policy = bed.faas.enable_auto_reschedule(AutoRescheduleConfig {
            min_interval_s: 0.0,
            ..AutoRescheduleConfig::default()
        });
        // A successful run populates the per-(function, resource) EWMA.
        let run = bed.faas.submit_workflow("mono", &HashMap::new()).unwrap();
        bed.faas.wait_workflow(run, 10.0).unwrap();
        assert!(
            policy.ewma("mono", "f", bed.edges[0]).is_some(),
            "NodeCompleted latencies feed the EWMA"
        );
        assert_eq!(policy.attempts(), 0, "INFINITY threshold: no latency trigger");
        // Saturate edge 0 (1 GB function cannot fit 0.5 GB free), then miss
        // a deadline: the policy must migrate the app's hottest function.
        let reg0 = bed.faas.resource(bed.edges[0]).unwrap();
        bed.executor.register("img/noop", |_: &[u8]| Ok(vec![]));
        reg0.handle.deploy("hog", "img/noop", 127 << 29, 0, &[]).unwrap();
        reg0.handle.invoke("hog", &Bytes::new()).unwrap();
        let run = bed
            .faas
            .submit_workflow_qos(
                "mono",
                &HashMap::new(),
                QoS::class(Priority::Interactive).with_deadline(0.0),
            )
            .unwrap();
        assert!(bed.faas.wait_workflow(run, 10.0).is_err(), "deadline 0 must miss");
        // The migration job is asynchronous: poll for the new placement.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            if bed.faas.candidates_of("mono", "f").unwrap() == vec![bed.edges[1]] {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "auto-reschedule did not migrate mono.f off the saturated edge"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(policy.attempts() >= 1);
        assert!(policy.moved() >= 1);
        // The deployment followed the placement (make-before-break).
        let reg1 = bed.faas.resource(bed.edges[1]).unwrap();
        assert!(reg1.handle.list().unwrap().contains(&"mono.f".to_string()));
    }

    #[test]
    fn auto_reschedule_latency_trigger_is_rate_limited() {
        let bed = mono_bed();
        let policy = bed.faas.enable_auto_reschedule(AutoRescheduleConfig {
            alpha: 1.0,
            // Any real invocation latency exceeds a zero threshold.
            latency_threshold_s: 0.0,
            min_interval_s: 3600.0,
            ..AutoRescheduleConfig::default()
        });
        for _ in 0..3 {
            let run = bed.faas.submit_workflow("mono", &HashMap::new()).unwrap();
            bed.faas.wait_workflow(run, 10.0).unwrap();
        }
        assert_eq!(
            policy.attempts(),
            1,
            "three threshold crossings inside the rate-limit window = one attempt"
        );
        // Give the (asynchronous) migration job time to run: no load
        // changed, so it must not move anything.
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert_eq!(policy.moved(), 0, "same load, same placement");
        assert_eq!(bed.faas.candidates_of("mono", "f").unwrap(), vec![bed.edges[0]]);
    }

    #[test]
    fn reschedule_migrates_away_from_saturated_resource() {
        let bed = configured();
        bed.executor.register("img/noop", |_: &[u8]| Ok(vec![]));
        let pkg = FunctionPackage { code: "img/noop".into() };
        // A single-placement edge function anchored near set 1.
        let yaml = "\
application: mono
entrypoint: f
dag:
  - name: f
    requirements:
      memory: 1024MB
    affinity:
      nodetype: edge
      affinitytype: data
    reduce: 1
";
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![bed.iot[0]]);
        let plan = bed.faas.configure_application(yaml, &data).unwrap();
        assert_eq!(plan["f"], vec![bed.edges[0]], "closest edge first");
        bed.faas.deploy_function("mono", "f", &pkg).unwrap();
        // Saturate edge 0's memory: a hog leaves only 0.5 GB free (< f's 1 GB) and
        // invoke it so sandboxes are admitted.
        let hog_backend = {
            let reg = bed.faas.resource(bed.edges[0]).unwrap();
            reg.handle.deploy("hog", "img/noop", 127 << 29, 0, &[]).unwrap(); // 63.5 GB of 64
            reg
        };
        hog_backend.handle.invoke("hog", &Bytes::new()).unwrap();
        // Rescheduling must now move `f` to the other edge.
        let (old, new) =
            bed.faas.reschedule_function("mono", "f", &pkg, vec![bed.iot[0]]).unwrap();
        assert_eq!(old, vec![bed.edges[0]]);
        assert_eq!(new, vec![bed.edges[1]], "migrated to the unloaded edge");
        // Old deployment removed, new one live.
        let reg0 = bed.faas.resource(bed.edges[0]).unwrap();
        assert!(!reg0.handle.list().unwrap().contains(&"mono.f".to_string()));
        let reg1 = bed.faas.resource(bed.edges[1]).unwrap();
        assert!(reg1.handle.list().unwrap().contains(&"mono.f".to_string()));
    }

    /// A policy handle detached from any coordinator, for exercising the
    /// admission gates against explicit clock values.
    fn bare_policy(cfg: AutoRescheduleConfig) -> AutoRescheduler {
        AutoRescheduler {
            cfg,
            ewma: Mutex::new(HashMap::new()),
            outcomes: Mutex::new(HashMap::new()),
            last_attempt: Mutex::new(HashMap::new()),
            app_attempts: Mutex::new(HashMap::new()),
            inflight: Mutex::new(HashSet::new()),
            attempts: AtomicU64::new(0),
            moved: AtomicU64::new(0),
        }
    }

    #[test]
    fn idle_ewma_decays_with_half_life() {
        let policy = bare_policy(AutoRescheduleConfig {
            alpha: 1.0,
            idle_half_life_s: 10.0,
            ..AutoRescheduleConfig::default()
        });
        policy.observe("a.f", 1, 8.0, 0.0);
        assert_eq!(policy.max_effective("a.f", 0.0), Some(8.0));
        // Three half-lives idle: 8 → 1.
        let cooled = policy.max_effective("a.f", 30.0).unwrap();
        assert!((cooled - 1.0).abs() < 1e-9, "8.0 over 3 half-lives = 1.0, got {cooled}");
        // The next sample folds into the *cooled* value, not the stale one:
        // alpha 1.0 means the sample replaces it outright.
        assert_eq!(policy.observe("a.f", 1, 2.0, 30.0), 2.0);
        // A colder placement never outranks a recently-hot one.
        policy.observe("a.g", 2, 1.5, 30.0);
        assert_eq!(policy.hottest_of_app("a", 30.0), Some("a.f".to_string()));
        // ...but decay can flip the ranking once the hot one idles. a.f was
        // last seen at t=30 with 2.0; a.g refreshed at t=50 stays 1.5 while
        // a.f has cooled to 2.0 · 0.5² = 0.5 by t=50.
        policy.observe("a.g", 2, 1.5, 50.0);
        assert_eq!(policy.hottest_of_app("a", 50.0), Some("a.g".to_string()));
    }

    #[test]
    fn unhelpful_migration_enters_cooldown() {
        let policy = bare_policy(AutoRescheduleConfig {
            alpha: 1.0,
            min_interval_s: 0.0,
            cooldown_s: 100.0,
            improvement_factor: 0.9,
            // Disable decay so hotness only moves via samples.
            idle_half_life_s: f64::INFINITY,
            ..AutoRescheduleConfig::default()
        });
        policy.observe("a.f", 1, 10.0, 0.0);
        assert!(policy.admit_attempt("a.f", 1.0), "first attempt always admitted");
        policy.inflight.lock().unwrap().remove("a.f"); // migration job finished
        // Hotness unchanged (10 > 0.9 · 10): inside the cooldown the
        // re-trigger is refused even though min_interval_s is 0.
        assert!(!policy.admit_attempt("a.f", 5.0), "unimproved + in cooldown = refused");
        // The migration helped after all (10 → 0.5): cooldown lifts early.
        policy.observe("a.f", 1, 0.5, 6.0);
        assert!(policy.admit_attempt("a.f", 6.0), "improvement lifts the cooldown");
        policy.inflight.lock().unwrap().remove("a.f");
        // That second migration didn't help (0.5 vs pre 0.5) → refused again…
        assert!(!policy.admit_attempt("a.f", 7.0));
        // …until the cooldown itself lapses.
        assert!(policy.admit_attempt("a.f", 200.0), "cooldown expiry re-admits");
    }

    #[test]
    fn per_app_migration_budget_is_a_sliding_window() {
        let policy = bare_policy(AutoRescheduleConfig {
            min_interval_s: 0.0,
            cooldown_s: 0.0,
            max_migrations_per_app: 2,
            migration_window_s: 10.0,
            ..AutoRescheduleConfig::default()
        });
        // Two different functions of one app drain the shared app budget…
        assert!(policy.admit_attempt("a.f", 0.0));
        policy.inflight.lock().unwrap().remove("a.f");
        assert!(policy.admit_attempt("a.g", 1.0));
        policy.inflight.lock().unwrap().remove("a.g");
        // …refusing a third function inside the window, while another
        // app's budget is untouched.
        assert!(!policy.admit_attempt("a.h", 2.0), "app budget exhausted");
        assert!(policy.admit_attempt("b.f", 2.0), "budget is per app");
        // A budget refusal leaves the per-function gates untouched (no
        // rate-limit timestamp, no cooldown entry), so once the t=0
        // attempt slides out of the 10 s window, a.h admits normally.
        assert!(policy.admit_attempt("a.h", 10.5), "window slid: t=0 attempt expired");
        // The budget counts *admitted* attempts only — the t=2 refusal
        // left no trace. In-window now: a.g (t=1) and a.h (t=10.5), so
        // the window is full again.
        assert!(!policy.admit_attempt("a.f", 10.6), "window refilled by the t=10.5 admit");
    }
}
