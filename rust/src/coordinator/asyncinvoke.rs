//! Asynchronous invocation + load-driven rescheduling — the asynchronous
//! front-end over the event-driven execution engine.
//!
//! §3.2.1: "A function can be invoked synchronously (and wait for the
//! response), or asynchronously. To invoke a function asynchronously, set
//! Sync to False." — [`EdgeFaaS::invoke_async`] submits a job to the
//! engine's shared worker pool ([`EdgeFaaS::spawn_job`]) and returns an
//! invocation id immediately; results are polled (or awaited) through the
//! tracker, the OpenFaaS async-function pattern. Because the job runs on
//! the same pool as workflow instances, async invocations are subject to
//! the same worker cap and interleave fairly with in-flight workflow runs.
//! Jobs ride the engine's sharded dispatch queues like instances do
//! (spread across shards by submission sequence), so a burst of async
//! invocations does not serialize against workflow dispatch on any global
//! lock.
//!
//! §3.1.2 + the NanoLambda comparison (§6: NanoLambda "does not follow the
//! dynamic changes of system loads ... to reschedule functions" — implying
//! EdgeFaaS does): [`EdgeFaaS::reschedule_function`] re-runs the two-phase
//! scheduler against *current* monitoring data and migrates deployments
//! whose placement changed.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::bytes::Bytes;
use crate::util::json::Json;

use super::engine::QoS;
use super::functions::FunctionPackage;
use super::resource::{EdgeFaaS, ResourceId};
use super::scheduler::FunctionCreation;

/// Handle for one asynchronous invocation.
pub type InvocationId = u64;

/// Status of an async invocation. Outputs are shared [`Bytes`]: polling or
/// cloning a completed status bumps refcounts instead of copying payloads.
#[derive(Debug, Clone)]
pub enum AsyncStatus {
    Pending,
    Done(Vec<(ResourceId, Bytes, f64)>),
    Failed(String),
}

/// Tracker for in-flight async invocations.
#[derive(Default)]
pub struct AsyncTracker {
    next: AtomicU64,
    state: Mutex<HashMap<InvocationId, AsyncStatus>>,
    cv: Condvar,
}

impl AsyncTracker {
    pub fn new() -> Arc<AsyncTracker> {
        Arc::new(AsyncTracker::default())
    }

    fn begin(&self) -> InvocationId {
        let id = self.next.fetch_add(1, Ordering::SeqCst);
        self.state.lock().unwrap().insert(id, AsyncStatus::Pending);
        id
    }

    fn finish(&self, id: InvocationId, status: AsyncStatus) {
        self.state.lock().unwrap().insert(id, status);
        self.cv.notify_all();
    }

    /// Non-blocking poll.
    pub fn poll(&self, id: InvocationId) -> Option<AsyncStatus> {
        self.state.lock().unwrap().get(&id).cloned()
    }

    /// Block until the invocation completes (or `timeout_s` elapses).
    pub fn wait(&self, id: InvocationId, timeout_s: f64) -> anyhow::Result<AsyncStatus> {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs_f64(timeout_s);
        let mut guard = self.state.lock().unwrap();
        loop {
            match guard.get(&id) {
                None => anyhow::bail!("unknown invocation {id}"),
                Some(AsyncStatus::Pending) => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        anyhow::bail!("invocation {id} timed out");
                    }
                    let (g, _) = self.cv.wait_timeout(guard, deadline - now).unwrap();
                    guard = g;
                }
                Some(done) => return Ok(done.clone()),
            }
        }
    }

    /// Drop a completed invocation's record.
    pub fn forget(&self, id: InvocationId) {
        self.state.lock().unwrap().remove(&id);
    }
}

impl EdgeFaaS {
    /// Invoke() with Sync=False: submit a job to the execution engine's
    /// worker pool, return the invocation id immediately. Results land in
    /// `tracker`. Submits under the default [`QoS`] (`Interactive`); see
    /// [`Self::invoke_async_qos`].
    pub fn invoke_async(
        self: &Arc<Self>,
        tracker: &Arc<AsyncTracker>,
        app: &str,
        function: &str,
        payload: &Json,
        invoke_one: bool,
    ) -> InvocationId {
        self.invoke_async_qos(tracker, app, function, payload, invoke_one, QoS::default())
    }

    /// [`Self::invoke_async`] under an explicit [`QoS`]: the class orders
    /// the invocation's job against every queued workflow instance and job
    /// (a `Batch` async invocation yields to `Realtime` workflow work), and
    /// a deadline is an EDF ordering hint — single invocations are opaque
    /// jobs, so they are never deadline-cancelled.
    pub fn invoke_async_qos(
        self: &Arc<Self>,
        tracker: &Arc<AsyncTracker>,
        app: &str,
        function: &str,
        payload: &Json,
        invoke_one: bool,
        qos: QoS,
    ) -> InvocationId {
        let id = tracker.begin();
        let tracker = Arc::clone(tracker);
        let (app, function, payload) = (app.to_string(), function.to_string(), payload.clone());
        self.spawn_job_qos(qos, move |faas| {
            let status = match faas.invoke(&app, &function, &payload, invoke_one) {
                Ok(results) => AsyncStatus::Done(results),
                Err(e) => AsyncStatus::Failed(e.to_string()),
            };
            tracker.finish(id, status);
        });
        id
    }

    /// Re-run two-phase scheduling for a deployed function against current
    /// monitoring data; if the placement changed, deploy on the new
    /// resources and remove from the abandoned ones. Returns
    /// `(old, new)` placements.
    pub fn reschedule_function(
        &self,
        app: &str,
        function: &str,
        package: &FunctionPackage,
        data_locations: Vec<ResourceId>,
    ) -> anyhow::Result<(Vec<ResourceId>, Vec<ResourceId>)> {
        let application = self.app(app)?;
        let cfg = application
            .config
            .function(function)
            .ok_or_else(|| anyhow::anyhow!("no function `{function}` in `{app}`"))?
            .clone();
        let old = self.candidates_of(app, function)?;
        // Dependency placements as currently recorded.
        let mut dep_locations = Vec::new();
        for d in &cfg.dependencies {
            dep_locations.extend(self.candidates_of(app, d).unwrap_or_default());
        }
        let request = FunctionCreation {
            app: app.to_string(),
            function: cfg,
            data_locations,
            dep_locations,
        };
        let new = self.schedule_function(&request)?;
        if new == old {
            return Ok((old.clone(), new));
        }
        let qname = Self::qualified(app, function);
        // Deploy on newly-chosen resources first (make-before-break), then
        // remove from the abandoned ones.
        let labels =
            vec![("app".to_string(), app.to_string()), ("fn".to_string(), function.to_string())];
        for &rid in new.iter().filter(|r| !old.contains(r)) {
            let reg = self.resource(rid)?;
            reg.handle.deploy(
                &qname,
                &package.code,
                request_memory(self, app, function)?,
                0,
                &labels,
            )?;
        }
        for &rid in old.iter().filter(|r| !new.contains(r)) {
            if let Ok(reg) = self.resource(rid) {
                let _ = reg.handle.remove(&qname);
            }
        }
        log::info!("rescheduled {qname}: {old:?} -> {new:?}");
        Ok((old, new))
    }
}

fn request_memory(faas: &EdgeFaaS, app: &str, function: &str) -> anyhow::Result<u64> {
    Ok(faas
        .app(app)?
        .config
        .function(function)
        .map(|f| f.requirements.memory)
        .unwrap_or(128 << 20))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::appconfig::federated_learning_yaml;
    use crate::simnet::RealClock;
    use crate::testbed::paper_testbed;

    fn configured() -> crate::testbed::TestBed {
        let bed = paper_testbed(Arc::new(RealClock::new()));
        let mut data = HashMap::new();
        data.insert("train".to_string(), bed.iot.clone());
        bed.faas.configure_application(federated_learning_yaml(), &data).unwrap();
        bed
    }

    #[test]
    fn async_invoke_completes_and_is_pollable() {
        let bed = configured();
        bed.executor.register("img/slow", |p: &[u8]| {
            std::thread::sleep(std::time::Duration::from_millis(50));
            Ok(p.to_vec())
        });
        bed.faas
            .deploy_function(
                "federatedlearning",
                "secondaggregation",
                &FunctionPackage { code: "img/slow".into() },
            )
            .unwrap();
        let tracker = AsyncTracker::new();
        let id = bed.faas.invoke_async(
            &tracker,
            "federatedlearning",
            "secondaggregation",
            &Json::obj(),
            true,
        );
        // Immediately pending (the handler sleeps 50 ms).
        assert!(matches!(tracker.poll(id), Some(AsyncStatus::Pending)));
        let status = tracker.wait(id, 5.0).unwrap();
        match status {
            AsyncStatus::Done(results) => assert_eq!(results.len(), 1),
            other => panic!("unexpected status {other:?}"),
        }
        tracker.forget(id);
        assert!(tracker.poll(id).is_none());
    }

    #[test]
    fn async_failure_is_reported() {
        let bed = configured();
        bed.executor.register("img/fail", |_: &[u8]| anyhow::bail!("boom"));
        bed.faas
            .deploy_function(
                "federatedlearning",
                "secondaggregation",
                &FunctionPackage { code: "img/fail".into() },
            )
            .unwrap();
        let tracker = AsyncTracker::new();
        let id = bed.faas.invoke_async(
            &tracker,
            "federatedlearning",
            "secondaggregation",
            &Json::obj(),
            true,
        );
        match tracker.wait(id, 5.0).unwrap() {
            AsyncStatus::Failed(msg) => assert!(msg.contains("boom"), "{msg}"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn wait_times_out_and_unknown_id_errors() {
        let tracker = AsyncTracker::new();
        assert!(tracker.wait(999, 0.05).is_err(), "unknown id");
        let id = tracker.begin();
        assert!(tracker.wait(id, 0.05).is_err(), "times out while pending");
    }

    #[test]
    fn reschedule_is_stable_without_load_change() {
        let bed = configured();
        bed.executor.register("img/noop", |_: &[u8]| Ok(vec![]));
        let pkg = FunctionPackage { code: "img/noop".into() };
        bed.faas.deploy_function("federatedlearning", "train", &pkg).unwrap();
        let (old, new) = bed
            .faas
            .reschedule_function("federatedlearning", "train", &pkg, bed.iot.clone())
            .unwrap();
        assert_eq!(old, new, "same load, same placement");
    }

    #[test]
    fn reschedule_migrates_away_from_saturated_resource() {
        let bed = configured();
        bed.executor.register("img/noop", |_: &[u8]| Ok(vec![]));
        let pkg = FunctionPackage { code: "img/noop".into() };
        // A single-placement edge function anchored near set 1.
        let yaml = "\
application: mono
entrypoint: f
dag:
  - name: f
    requirements:
      memory: 1024MB
    affinity:
      nodetype: edge
      affinitytype: data
    reduce: 1
";
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![bed.iot[0]]);
        let plan = bed.faas.configure_application(yaml, &data).unwrap();
        assert_eq!(plan["f"], vec![bed.edges[0]], "closest edge first");
        bed.faas.deploy_function("mono", "f", &pkg).unwrap();
        // Saturate edge 0's memory: a hog leaves only 0.5 GB free (< f's 1 GB) and
        // invoke it so sandboxes are admitted.
        let hog_backend = {
            let reg = bed.faas.resource(bed.edges[0]).unwrap();
            reg.handle.deploy("hog", "img/noop", 127 << 29, 0, &[]).unwrap(); // 63.5 GB of 64
            reg
        };
        hog_backend.handle.invoke("hog", &Bytes::new()).unwrap();
        // Rescheduling must now move `f` to the other edge.
        let (old, new) =
            bed.faas.reschedule_function("mono", "f", &pkg, vec![bed.iot[0]]).unwrap();
        assert_eq!(old, vec![bed.edges[0]]);
        assert_eq!(new, vec![bed.edges[1]], "migrated to the unloaded edge");
        // Old deployment removed, new one live.
        let reg0 = bed.faas.resource(bed.edges[0]).unwrap();
        assert!(!reg0.handle.list().unwrap().contains(&"mono.f".to_string()));
        let reg1 = bed.faas.resource(bed.edges[1]).unwrap();
        assert!(reg1.handle.list().unwrap().contains(&"mono.f".to_string()));
    }
}
