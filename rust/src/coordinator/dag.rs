//! DAG construction over an application configuration (§3.2.2).
//!
//! "EdgeFaaS stores the application specifications in a Directed acyclic
//! graph (DAG). The functions are the nodes and the dependencies are the
//! edges." The DAG provides the topological order the deployer walks (a
//! function's placement depends on its dependencies' placements) and the
//! readiness bookkeeping the invoker uses for workflow chaining.

use std::collections::{HashMap, HashSet, VecDeque};

use super::appconfig::AppConfig;

/// A validated DAG with topological order.
#[derive(Debug, Clone)]
pub struct Dag {
    /// Function names in a valid topological order (dependencies first).
    pub topo_order: Vec<String>,
    /// name -> indices of dependent functions (edges out).
    pub dependents: HashMap<String, Vec<String>>,
    /// name -> dependency names (edges in).
    pub dependencies: HashMap<String, Vec<String>>,
}

impl Dag {
    /// Build and cycle-check the DAG (Kahn's algorithm).
    pub fn build(cfg: &AppConfig) -> anyhow::Result<Dag> {
        let mut indeg: HashMap<&str, usize> = HashMap::new();
        let mut dependents: HashMap<String, Vec<String>> = HashMap::new();
        let mut dependencies: HashMap<String, Vec<String>> = HashMap::new();
        for f in &cfg.functions {
            indeg.entry(f.name.as_str()).or_insert(0);
            dependencies.insert(f.name.clone(), f.dependencies.clone());
            for d in &f.dependencies {
                *indeg.entry(f.name.as_str()).or_insert(0) += 1;
                dependents.entry(d.clone()).or_default().push(f.name.clone());
            }
        }
        let mut queue: VecDeque<&str> = cfg
            .functions
            .iter()
            .filter(|f| indeg[f.name.as_str()] == 0)
            .map(|f| f.name.as_str())
            .collect();
        let mut topo = Vec::with_capacity(cfg.functions.len());
        while let Some(n) = queue.pop_front() {
            topo.push(n.to_string());
            if let Some(deps) = dependents.get(n) {
                for d in deps.clone() {
                    let e = indeg.get_mut(d.as_str()).unwrap();
                    *e -= 1;
                    if *e == 0 {
                        queue.push_back(cfg.function(&d).unwrap().name.as_str());
                    }
                }
            }
        }
        if topo.len() != cfg.functions.len() {
            let stuck: Vec<&str> = cfg
                .functions
                .iter()
                .map(|f| f.name.as_str())
                .filter(|n| !topo.iter().any(|t| t == n))
                .collect();
            anyhow::bail!("dependency cycle involving {stuck:?}");
        }
        Ok(Dag { topo_order: topo, dependents, dependencies })
    }

    /// Source functions (no dependencies).
    pub fn sources(&self) -> Vec<&str> {
        self.topo_order
            .iter()
            .filter(|n| self.dependencies.get(*n).map(|d| d.is_empty()).unwrap_or(true))
            .map(String::as_str)
            .collect()
    }

    /// Sink functions (no dependents).
    pub fn sinks(&self) -> Vec<&str> {
        self.topo_order
            .iter()
            .filter(|n| self.dependents.get(*n).map(|d| d.is_empty()).unwrap_or(true))
            .map(String::as_str)
            .collect()
    }

    /// All transitive dependencies of `name` (not including itself).
    pub fn ancestors(&self, name: &str) -> HashSet<String> {
        let mut out = HashSet::new();
        let mut stack: Vec<&str> = self
            .dependencies
            .get(name)
            .map(|d| d.iter().map(String::as_str).collect())
            .unwrap_or_default();
        while let Some(n) = stack.pop() {
            if out.insert(n.to_string()) {
                if let Some(deps) = self.dependencies.get(n) {
                    stack.extend(deps.iter().map(String::as_str));
                }
            }
        }
        out
    }
}

/// Readiness tracker for one workflow run: a function fires when all its
/// dependencies have completed (the invoker's join logic).
#[derive(Debug)]
pub struct RunState {
    remaining: HashMap<String, usize>,
    done: HashSet<String>,
}

impl RunState {
    pub fn new(dag: &Dag) -> RunState {
        let remaining = dag
            .dependencies
            .iter()
            .map(|(k, v)| (k.clone(), v.len()))
            .collect();
        RunState { remaining, done: HashSet::new() }
    }

    /// Mark `name` complete; returns the newly-ready dependents.
    pub fn complete(&mut self, dag: &Dag, name: &str) -> Vec<String> {
        if !self.done.insert(name.to_string()) {
            return Vec::new(); // already completed
        }
        let mut ready = Vec::new();
        if let Some(deps) = dag.dependents.get(name) {
            for d in deps {
                let r = self.remaining.get_mut(d).expect("known function");
                *r -= 1;
                if *r == 0 {
                    ready.push(d.clone());
                }
            }
        }
        ready
    }

    pub fn is_done(&self, name: &str) -> bool {
        self.done.contains(name)
    }

    pub fn all_done(&self) -> bool {
        self.done.len() == self.remaining.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::appconfig::{federated_learning_yaml, video_pipeline_yaml};
    use crate::util::yaml;

    fn fl() -> (AppConfig, Dag) {
        let cfg = AppConfig::from_yaml(&yaml::parse(federated_learning_yaml()).unwrap()).unwrap();
        let dag = Dag::build(&cfg).unwrap();
        (cfg, dag)
    }

    #[test]
    fn topo_order_respects_dependencies() {
        let (_, dag) = fl();
        let pos = |n: &str| dag.topo_order.iter().position(|x| x == n).unwrap();
        assert!(pos("train") < pos("firstaggregation"));
        assert!(pos("firstaggregation") < pos("secondaggregation"));
    }

    #[test]
    fn sources_and_sinks() {
        let (_, dag) = fl();
        assert_eq!(dag.sources(), vec!["train"]);
        assert_eq!(dag.sinks(), vec!["secondaggregation"]);
    }

    #[test]
    fn video_pipeline_is_a_chain() {
        let cfg = AppConfig::from_yaml(&yaml::parse(video_pipeline_yaml()).unwrap()).unwrap();
        let dag = Dag::build(&cfg).unwrap();
        assert_eq!(
            dag.topo_order,
            vec![
                "video-generator",
                "video-processing",
                "motion-detection",
                "face-detection",
                "face-extraction",
                "face-recognition"
            ]
        );
        assert_eq!(dag.ancestors("face-recognition").len(), 5);
        assert_eq!(dag.ancestors("video-generator").len(), 0);
    }

    #[test]
    fn run_state_joins_fan_in() {
        let doc = "\
application: join
entrypoint: a
dag:
  - name: a
    affinity:
      nodetype: iot
      affinitytype: data
  - name: b
    affinity:
      nodetype: iot
      affinitytype: data
  - name: j
    dependencies: a, b
    affinity:
      nodetype: cloud
      affinitytype: function
";
        let cfg = AppConfig::from_yaml(&yaml::parse(doc).unwrap()).unwrap();
        let dag = Dag::build(&cfg).unwrap();
        let mut rs = RunState::new(&dag);
        assert!(rs.complete(&dag, "a").is_empty(), "j not ready after a alone");
        assert_eq!(rs.complete(&dag, "b"), vec!["j"], "j ready after both");
        assert!(rs.complete(&dag, "b").is_empty(), "idempotent completion");
        rs.complete(&dag, "j");
        assert!(rs.all_done());
    }
}
