//! The coordinator's view of a registered resource.
//!
//! EdgeFaaS only ever touches resources through their gateways — "EdgeFaaS
//! uses HTTP to request the RESTful APIs provided by the FaaS framework and
//! object store" (§3.1) — so the coordinator is written against this trait.
//! Two implementations:
//!
//! * [`LocalHandle`] — direct in-process calls into the cluster/objstore/
//!   monitor substrates. Used by the virtual-time benches (no sockets in the
//!   simulated hot loop) and by tests.
//! * [`HttpHandle`] — real loopback HTTP against the per-resource gateways,
//!   exactly the wire path the paper describes. Used by the examples.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cluster::faas::{FaasBackend, FunctionSpec};
use crate::cluster::gateway::client as faas_client;
use crate::monitor::metrics::ResourceUsage;
use crate::objstore::gateway::client as store_client;
use crate::objstore::ObjectStore;

/// Abstract per-resource operations the coordinator needs.
pub trait ResourceHandle: Send + Sync {
    // ---- FaaS verbs (OpenFaaS gateway) ----
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()>;
    fn remove(&self, name: &str) -> anyhow::Result<()>;
    fn invoke(&self, name: &str, payload: &[u8]) -> anyhow::Result<(Vec<u8>, f64)>;
    fn list(&self) -> anyhow::Result<Vec<String>>;
    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json>;

    // ---- monitoring (Prometheus) ----
    fn usage(&self) -> anyhow::Result<ResourceUsage>;

    // ---- storage verbs (MinIO) ----
    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()>;
    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()>;
    fn put_object(&self, bucket: &str, object: &str, data: &[u8]) -> anyhow::Result<()>;
    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Vec<u8>>;
    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()>;
    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>>;
    /// Total bytes stored (unregistration requires zero).
    fn stored_bytes(&self) -> anyhow::Result<u64>;
}

/// Direct in-process handle.
pub struct LocalHandle {
    pub backend: Arc<FaasBackend>,
    pub store: Arc<ObjectStore>,
}

impl LocalHandle {
    pub fn new(backend: Arc<FaasBackend>, store: Arc<ObjectStore>) -> Self {
        LocalHandle { backend, store }
    }
}

impl ResourceHandle for LocalHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        let labels: HashMap<String, String> = labels.iter().cloned().collect();
        self.backend
            .deploy(FunctionSpec { name: name.into(), image: image.into(), memory, gpus, labels })
            .map_err(|e| anyhow::anyhow!(e))
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        self.backend.remove(name).map_err(|e| anyhow::anyhow!(e))
    }

    fn invoke(&self, name: &str, payload: &[u8]) -> anyhow::Result<(Vec<u8>, f64)> {
        self.backend.invoke(name, payload)
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        Ok(self.backend.list())
    }

    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json> {
        let st = self.backend.describe(name).map_err(|e| anyhow::anyhow!(e))?;
        let mut o = crate::util::json::Json::obj();
        o.set("name", st.spec.name.as_str().into())
            .set("image", st.spec.image.as_str().into())
            .set("replicas", (st.replicas as u64).into())
            .set("invocations", st.invocations.into())
            .set("url", st.url.as_str().into());
        Ok(o)
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        let spec = &self.backend.spec;
        Ok(ResourceUsage {
            cpu_frac: 0.0,
            mem_used: (self.backend.mem_utilization() * spec.total_memory() as f64) as u64,
            mem_total: spec.total_memory(),
            io_bytes_per_s: 0.0,
            gpu_frac: 0.0,
            gpus_used: 0,
            gpus_total: spec.total_gpus(),
        })
    }

    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        self.store.make_bucket(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        self.store.remove_bucket(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn put_object(&self, bucket: &str, object: &str, data: &[u8]) -> anyhow::Result<()> {
        self.store.put_object(bucket, object, data.to_vec()).map_err(|e| anyhow::anyhow!(e))
    }

    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Vec<u8>> {
        self.store.get_object(bucket, object).map_err(|e| anyhow::anyhow!(e))
    }

    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()> {
        self.store.remove_object(bucket, object).map_err(|e| anyhow::anyhow!(e))
    }

    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>> {
        self.store.list_objects(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn stored_bytes(&self) -> anyhow::Result<u64> {
        Ok(self.store.used())
    }
}

/// Loopback-HTTP handle: the full REST wire path.
pub struct HttpHandle {
    /// OpenFaaS-style gateway address (host:port).
    pub faas_addr: String,
    pub pwd: String,
    /// MinIO-style endpoint.
    pub minio_addr: String,
    pub access_key: String,
    pub secret_key: String,
    /// Prometheus endpoint ("" = no monitoring; usage() returns default).
    pub prometheus_addr: String,
}

impl ResourceHandle for HttpHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        faas_client::deploy(&self.faas_addr, &self.pwd, name, image, memory, gpus, labels)
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        faas_client::remove(&self.faas_addr, &self.pwd, name)
    }

    fn invoke(&self, name: &str, payload: &[u8]) -> anyhow::Result<(Vec<u8>, f64)> {
        faas_client::invoke(&self.faas_addr, name, payload)
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        faas_client::list(&self.faas_addr)
    }

    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json> {
        faas_client::describe(&self.faas_addr, name)
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        if self.prometheus_addr.is_empty() {
            return Ok(ResourceUsage::default());
        }
        crate::monitor::scrape::scrape(&self.prometheus_addr)
    }

    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        store_client::make_bucket(&self.minio_addr, &self.access_key, &self.secret_key, bucket)
    }

    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        store_client::remove_bucket(&self.minio_addr, &self.access_key, &self.secret_key, bucket)
    }

    fn put_object(&self, bucket: &str, object: &str, data: &[u8]) -> anyhow::Result<()> {
        store_client::put_object(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            object,
            data,
        )
    }

    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Vec<u8>> {
        store_client::get_object(&self.minio_addr, &self.access_key, &self.secret_key, bucket, object)
    }

    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()> {
        store_client::remove_object(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            object,
        )
    }

    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>> {
        store_client::list_objects(&self.minio_addr, &self.access_key, &self.secret_key, bucket)
    }

    fn stored_bytes(&self) -> anyhow::Result<u64> {
        // Sum object sizes across buckets via the REST interface.
        let mut total = 0u64;
        let resp = crate::util::http::request(
            &self.minio_addr,
            "GET",
            "/buckets",
            &[("X-Access-Key", &self.access_key), ("X-Secret-Key", &self.secret_key)],
            &[],
        )?;
        if !resp.ok() {
            anyhow::bail!("list buckets: {}", resp.status);
        }
        let buckets: Vec<String> = resp
            .json_body()?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|b| b.as_str().map(String::from))
            .collect();
        for b in buckets {
            for o in self.list_objects(&b)? {
                total += self.get_object(&b, &o)?.len() as u64;
            }
        }
        Ok(total)
    }
}
