//! The coordinator's view of a registered resource.
//!
//! EdgeFaaS only ever touches resources through their gateways — "EdgeFaaS
//! uses HTTP to request the RESTful APIs provided by the FaaS framework and
//! object store" (§3.1) — so the coordinator is written against this trait.
//! Two implementations:
//!
//! * [`LocalHandle`] — direct in-process calls into the cluster/objstore/
//!   monitor substrates. Used by the virtual-time benches (no sockets in the
//!   simulated hot loop) and by tests.
//! * [`HttpHandle`] — real loopback HTTP against the per-resource gateways,
//!   exactly the wire path the paper describes. Used by the examples.
//!
//! # Budgets and retries (the edge-link contract)
//!
//! Every [`HttpHandle`] verb runs under a per-verb deadline from its
//! [`VerbBudgets`]: control verbs get seconds, the `/metrics` liveness
//! probe a tight budget, object transfers more, and invokes derive their
//! deadline from the run's QoS deadline when one rides the
//! [`BatchCall::budget`] field. **Idempotent** verbs (deploy, list,
//! describe, usage, get_object, list_objects, stored_bytes) retry
//! connection-level failures ([`HttpError::is_connectivity`]) with bounded
//! exponential backoff + jitter. Invokes never blindly retry: the batch
//! path re-sends **at most once**, and only when every call carries a
//! nonzero attempt id — the backend's attempt-dedup cache then replays any
//! entry that already executed, preserving at-most-once execution.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::faas::{FaasBackend, FunctionSpec};
pub use crate::cluster::faas::BatchCall;
use crate::cluster::gateway::client as faas_client;
use crate::monitor::metrics::ResourceUsage;
use crate::monitor::scrape::ScrapeFailure;
use crate::objstore::gateway::client as store_client;
use crate::objstore::ObjectStore;
use crate::util::bytes::Bytes;
use crate::util::http::{HttpError, RequestOptions};

/// Abstract per-resource operations the coordinator needs.
///
/// The data plane (`invoke` / `invoke_batch` / `put_object` / `get_object`)
/// moves shared [`Bytes`]: against a [`LocalHandle`] no payload is ever
/// copied (refcount bumps end to end); the [`HttpHandle`] copies exactly
/// once per direction at the wire.
pub trait ResourceHandle: Send + Sync {
    // ---- FaaS verbs (OpenFaaS gateway) ----
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()>;
    fn remove(&self, name: &str) -> anyhow::Result<()>;
    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)>;
    /// The backend protocol's `Batch` verb: invoke several functions in one
    /// gateway round trip, one result per entry. Each call carries its
    /// engine attempt id ([`BatchCall`]) so the backend can deduplicate
    /// liveness-plane retries at-most-once. The default implementation
    /// falls back to per-task [`ResourceHandle::invoke`] for backends that
    /// do not support batching (dropping dedup — acceptable for ad-hoc
    /// handles; the engine paths use [`LocalHandle`]/[`HttpHandle`]).
    fn invoke_batch(&self, calls: &[BatchCall]) -> Vec<anyhow::Result<(Bytes, f64)>> {
        calls.iter().map(|c| self.invoke(&c.name, &c.payload)).collect()
    }
    fn list(&self) -> anyhow::Result<Vec<String>>;
    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json>;

    // ---- monitoring (Prometheus) ----
    fn usage(&self) -> anyhow::Result<ResourceUsage>;

    // ---- storage verbs (MinIO) ----
    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()>;
    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()>;
    fn put_object(&self, bucket: &str, object: &str, data: Bytes) -> anyhow::Result<()>;
    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Bytes>;
    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()>;
    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>>;
    /// Total bytes stored (unregistration requires zero).
    fn stored_bytes(&self) -> anyhow::Result<u64>;
}

/// Direct in-process handle.
pub struct LocalHandle {
    pub backend: Arc<FaasBackend>,
    pub store: Arc<ObjectStore>,
}

impl LocalHandle {
    pub fn new(backend: Arc<FaasBackend>, store: Arc<ObjectStore>) -> Self {
        LocalHandle { backend, store }
    }
}

impl ResourceHandle for LocalHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        let labels: HashMap<String, String> = labels.iter().cloned().collect();
        self.backend
            .deploy(FunctionSpec {
                name: name.into(),
                image: std::sync::Arc::from(image),
                memory,
                gpus,
                labels,
            })
            .map_err(|e| anyhow::anyhow!(e))
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        self.backend.remove(name).map_err(|e| anyhow::anyhow!(e))
    }

    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        self.backend.invoke(name, payload)
    }

    fn invoke_batch(&self, calls: &[BatchCall]) -> Vec<anyhow::Result<(Bytes, f64)>> {
        self.backend.invoke_batch(calls)
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        Ok(self.backend.list())
    }

    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json> {
        let st = self.backend.describe(name).map_err(|e| anyhow::anyhow!(e))?;
        let mut o = crate::util::json::Json::obj();
        o.set("name", st.spec.name.as_str().into())
            .set("image", (&*st.spec.image).into())
            .set("replicas", (st.replicas as u64).into())
            .set("invocations", st.invocations.into())
            .set("url", st.url.as_str().into());
        Ok(o)
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        let spec = &self.backend.spec;
        Ok(ResourceUsage {
            cpu_frac: 0.0,
            mem_used: (self.backend.mem_utilization() * spec.total_memory() as f64) as u64,
            mem_total: spec.total_memory(),
            io_bytes_per_s: 0.0,
            gpu_frac: 0.0,
            gpus_used: 0,
            gpus_total: spec.total_gpus(),
        })
    }

    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        self.store.make_bucket(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        self.store.remove_bucket(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn put_object(&self, bucket: &str, object: &str, data: Bytes) -> anyhow::Result<()> {
        // Zero-copy: the shared buffer is moved into the store as-is.
        self.store.put_object(bucket, object, data).map_err(|e| anyhow::anyhow!(e))
    }

    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Bytes> {
        self.store.get_object(bucket, object).map_err(|e| anyhow::anyhow!(e))
    }

    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()> {
        self.store.remove_object(bucket, object).map_err(|e| anyhow::anyhow!(e))
    }

    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>> {
        self.store.list_objects(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn stored_bytes(&self) -> anyhow::Result<u64> {
        Ok(self.store.used())
    }
}

/// Per-verb deadline and retry budgets for an [`HttpHandle`] (see the
/// module docs for the contract). Defaults suit a healthy LAN; chaos tests
/// and edge deployments tighten them.
#[derive(Debug, Clone)]
pub struct VerbBudgets {
    /// Budget for establishing any new connection.
    pub connect: Duration,
    /// Control-plane verbs: deploy, remove, list, describe, bucket admin.
    pub control: Duration,
    /// The `/metrics` usage scrape — the liveness probe, kept tight so a
    /// partitioned resource costs one short budget per probe.
    pub usage: Duration,
    /// Object-store transfers (put/get/remove/list objects).
    pub object: Duration,
    /// Invoke and batch invoke, when no QoS deadline rides the call.
    pub invoke: Duration,
    /// Coordinator-to-coordinator federation verbs (gossip push, steal,
    /// completion reports, forwarded stats polls) — kept short: a
    /// partitioned peer must cost one small budget per tick, not wedge the
    /// federation driver (see [`super::federation`]).
    pub federation: Duration,
    /// Extra attempts for idempotent verbs after a connectivity failure.
    pub retries: u32,
    /// First backoff; doubles per retry up to [`VerbBudgets::backoff_cap`],
    /// then multiplied by a jitter factor in `[0.5, 1.5)`.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Master switch: `false` disables every handle-level retry (the
    /// fault bench's "retries off" arm).
    pub retry: bool,
}

impl Default for VerbBudgets {
    fn default() -> VerbBudgets {
        VerbBudgets {
            connect: Duration::from_secs(2),
            control: Duration::from_secs(10),
            usage: Duration::from_secs(3),
            object: Duration::from_secs(30),
            invoke: Duration::from_secs(60),
            federation: Duration::from_secs(5),
            retries: 2,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(1),
            retry: true,
        }
    }
}

/// Connection-level evidence the peer or path is unhealthy — the only
/// failures idempotent retries (and the data-path liveness reporter) act
/// on. Application-level failures (HTTP status, malformed body) mean the
/// peer is alive and are never retried here.
pub fn is_connectivity_error(e: &anyhow::Error) -> bool {
    if let Some(h) = HttpError::of(e) {
        return h.is_connectivity();
    }
    matches!(e.downcast_ref::<ScrapeFailure>(), Some(ScrapeFailure::Unreachable { .. }))
}

/// Loopback-HTTP handle: the full REST wire path.
///
/// Construct with [`HttpHandle::new`]: the handle carries a private peer
/// capability cache alongside the address fields, so struct-literal
/// construction (possible in older revisions) no longer compiles. Budgets
/// default to [`VerbBudgets::default`]; override with
/// [`HttpHandle::with_budgets`].
pub struct HttpHandle {
    /// OpenFaaS-style gateway address (host:port).
    pub faas_addr: String,
    pub pwd: String,
    /// MinIO-style endpoint.
    pub minio_addr: String,
    pub access_key: String,
    pub secret_key: String,
    /// Prometheus endpoint ("" = no monitoring; usage() returns default).
    pub prometheus_addr: String,
    /// Per-verb deadline/retry budgets.
    budgets: VerbBudgets,
    /// Peer capability cache: cleared the first time the gateway refuses
    /// the binary `_batch` frame format pre-execution (a JSON-only peer),
    /// so later batches skip the doomed binary round trip instead of
    /// shipping every payload twice.
    binary_batch_ok: std::sync::atomic::AtomicBool,
}

impl HttpHandle {
    pub fn new(
        faas_addr: impl Into<String>,
        pwd: impl Into<String>,
        minio_addr: impl Into<String>,
        access_key: impl Into<String>,
        secret_key: impl Into<String>,
        prometheus_addr: impl Into<String>,
    ) -> HttpHandle {
        HttpHandle {
            faas_addr: faas_addr.into(),
            pwd: pwd.into(),
            minio_addr: minio_addr.into(),
            access_key: access_key.into(),
            secret_key: secret_key.into(),
            prometheus_addr: prometheus_addr.into(),
            budgets: VerbBudgets::default(),
            binary_batch_ok: std::sync::atomic::AtomicBool::new(true),
        }
    }

    /// Replace the per-verb budgets (builder style).
    pub fn with_budgets(mut self, budgets: VerbBudgets) -> HttpHandle {
        self.budgets = budgets;
        self
    }

    /// The configured budgets.
    pub fn budgets(&self) -> &VerbBudgets {
        &self.budgets
    }

    fn opts(&self, deadline: Duration) -> RequestOptions {
        RequestOptions::budget(self.budgets.connect, deadline)
    }

    /// Exponential backoff for retry `attempt` (0-based), jittered by a
    /// factor in `[0.5, 1.5)` so synchronized retry storms decorrelate.
    /// Timing-only: jitter never feeds outcome determinism.
    fn backoff(&self, attempt: u32) -> Duration {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NONCE: AtomicU64 = AtomicU64::new(0);
        let exp = self
            .budgets
            .backoff_base
            .saturating_mul(1u32 << attempt.min(10))
            .min(self.budgets.backoff_cap);
        let mut rng = crate::util::rng::SplitMix64::seeded(
            NONCE.fetch_add(1, Ordering::Relaxed) ^ 0x5bf0_3635,
        );
        Duration::from_nanos((exp.as_nanos() as f64 * (0.5 + rng.next_f64())) as u64)
    }

    /// Run an idempotent verb, retrying up to `budgets.retries` extra
    /// times on connectivity failures (only — an HTTP error status means
    /// the peer answered and is returned as-is).
    fn retry_idempotent<T>(&self, f: impl Fn() -> anyhow::Result<T>) -> anyhow::Result<T> {
        let mut attempt = 0u32;
        loop {
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if !self.budgets.retry
                        || attempt >= self.budgets.retries
                        || !is_connectivity_error(&e)
                    {
                        return Err(e);
                    }
                    std::thread::sleep(self.backoff(attempt));
                    attempt += 1;
                }
            }
        }
    }

    /// Run one `_batch` wire leg with the at-most-once retry: a single
    /// re-send after a connectivity failure, and only when `dedup_safe`
    /// (every call carries a nonzero attempt id, so the backend's attempt
    /// cache replays anything that already executed).
    fn batch_leg(
        &self,
        dedup_safe: bool,
        f: impl Fn() -> anyhow::Result<faas_client::BatchAttempt>,
    ) -> anyhow::Result<faas_client::BatchAttempt> {
        match f() {
            Err(e) if self.budgets.retry && dedup_safe && is_connectivity_error(&e) => {
                std::thread::sleep(self.backoff(0));
                f()
            }
            r => r,
        }
    }
}

impl ResourceHandle for HttpHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        // Idempotent: re-deploying the same spec converges, so a lost
        // reply is safely re-sent.
        self.retry_idempotent(|| {
            faas_client::deploy_with(
                &self.faas_addr,
                &self.pwd,
                name,
                image,
                memory,
                gpus,
                labels,
                self.opts(self.budgets.control),
            )
        })
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        faas_client::remove_with(&self.faas_addr, &self.pwd, name, self.opts(self.budgets.control))
    }

    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        // The client already returns a shared buffer (a window into the
        // HTTP response); no re-wrap copy. Never retried: the single-call
        // verb carries no attempt id, so a re-send could double-execute.
        faas_client::invoke_with(&self.faas_addr, name, payload, self.opts(self.budgets.invoke))
    }

    fn invoke_batch(&self, calls: &[BatchCall]) -> Vec<anyhow::Result<(Bytes, f64)>> {
        // One wire round trip: the length-prefixed binary frame format
        // (raw payloads/outputs — binary data travels at 1x instead of the
        // JSON leg's 2x hex), downgrading to the JSON format for old
        // peers. A peer's pre-execution refusal of the binary frames is
        // cached (`binary_batch_ok`), so a JSON-only gateway costs the
        // double round trip exactly once, not on every batch. Fallbacks
        // happen only when the batch verifiably did NOT execute
        // (`Refused` = pre-execution rejection); ambiguous failures —
        // transport/parse errors after the gateway may have executed the
        // batch — fail every entry instead of retrying, so non-idempotent
        // handlers never run twice.
        use crate::cluster::gateway::client::BatchAttempt;
        use std::sync::atomic::Ordering;
        // The batch deadline is the tightest per-call budget (the engine
        // derives those from run QoS deadlines); without one, the handle's
        // invoke budget applies.
        let batch_budget =
            calls.iter().filter_map(|c| c.budget).min().unwrap_or(self.budgets.invoke);
        let opts = self.opts(batch_budget);
        // At-most-once re-send is only safe when every call is covered by
        // the backend's attempt-dedup cache (attempt 0 = no dedup).
        let dedup_safe = !calls.is_empty() && calls.iter().all(|c| c.attempt != 0);
        // Fan a batch-wide failure out to every entry, keeping the typed
        // [`HttpError`] payload downcastable per entry — the engine's
        // data-path liveness reporter classifies these.
        let fail_all = |e: anyhow::Error| -> Vec<anyhow::Result<(Bytes, f64)>> {
            let typed = crate::util::http::HttpError::of(&e).cloned();
            let msg = e.to_string();
            calls
                .iter()
                .map(|_| match typed.clone() {
                    Some(he) => Err(anyhow::Error::new(he).context("batch invoke failed")),
                    None => Err(anyhow::anyhow!("batch invoke failed: {}", msg.clone())),
                })
                .collect()
        };
        if self.binary_batch_ok.load(Ordering::Relaxed) {
            match self.batch_leg(dedup_safe, || {
                faas_client::invoke_batch_binary_with(&self.faas_addr, calls, opts)
            }) {
                Ok(BatchAttempt::Ran(results)) => return results,
                Ok(BatchAttempt::Refused) => {
                    self.binary_batch_ok.store(false, Ordering::Relaxed);
                }
                Err(e) => return fail_all(e),
            }
        }
        match self.batch_leg(dedup_safe, || {
            faas_client::invoke_batch_json_with(&self.faas_addr, calls, opts)
        }) {
            Ok(BatchAttempt::Ran(results)) => results,
            // Both legs refused pre-execution (e.g. binary payloads
            // against a JSON-only peer): per-call invokes. The single-call
            // verb has no attempt field — dedup is lost on this legacy
            // path, exactly as for a pre-liveness peer.
            Ok(BatchAttempt::Refused) => {
                calls.iter().map(|c| self.invoke(&c.name, &c.payload)).collect()
            }
            Err(e) => fail_all(e),
        }
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        self.retry_idempotent(|| {
            faas_client::list_with(&self.faas_addr, self.opts(self.budgets.control))
        })
    }

    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json> {
        self.retry_idempotent(|| {
            faas_client::describe_with(&self.faas_addr, name, self.opts(self.budgets.control))
        })
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        if self.prometheus_addr.is_empty() {
            return Ok(ResourceUsage::default());
        }
        // The liveness probe: tight budget, bounded retries — so one
        // glitched scrape doesn't mark a resource Suspect, but a
        // partitioned one fails within a few short budgets.
        self.retry_idempotent(|| {
            crate::monitor::scrape::scrape_with(
                &self.prometheus_addr,
                self.opts(self.budgets.usage),
            )
        })
    }

    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        store_client::make_bucket_with(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            self.opts(self.budgets.control),
        )
    }

    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        store_client::remove_bucket_with(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            self.opts(self.budgets.control),
        )
    }

    fn put_object(&self, bucket: &str, object: &str, data: Bytes) -> anyhow::Result<()> {
        store_client::put_object_with(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            object,
            &data,
            self.opts(self.budgets.object),
        )
    }

    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Bytes> {
        self.retry_idempotent(|| {
            store_client::get_object_with(
                &self.minio_addr,
                &self.access_key,
                &self.secret_key,
                bucket,
                object,
                self.opts(self.budgets.object),
            )
        })
    }

    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()> {
        store_client::remove_object_with(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            object,
            self.opts(self.budgets.object),
        )
    }

    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>> {
        self.retry_idempotent(|| {
            store_client::list_objects_with(
                &self.minio_addr,
                &self.access_key,
                &self.secret_key,
                bucket,
                self.opts(self.budgets.object),
            )
        })
    }

    fn stored_bytes(&self) -> anyhow::Result<u64> {
        // Sum object sizes across buckets via the REST interface (rides a
        // pooled keep-alive connection like every other client call).
        let mut total = 0u64;
        let resp = self.retry_idempotent(|| {
            crate::util::http::request_with(
                &self.minio_addr,
                "GET",
                "/buckets",
                &[("X-Access-Key", &self.access_key), ("X-Secret-Key", &self.secret_key)],
                &[],
                self.opts(self.budgets.object),
            )
        })?;
        if !resp.ok() {
            anyhow::bail!("list buckets: {}", resp.status);
        }
        let buckets: Vec<String> = resp
            .json_body()?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|b| b.as_str().map(String::from))
            .collect();
        for b in buckets {
            for o in self.list_objects(&b)? {
                total += self.get_object(&b, &o)?.len() as u64;
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::faas::NativeExecutor;
    use crate::cluster::gateway::{FaasGateway, BATCH_BINARY_CONTENT_TYPE};
    use crate::cluster::spec::ResourceSpec;
    use crate::simnet::RealClock;
    use crate::util::http::{Handler, Request, Response, Server, ServerOptions};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    /// A JSON-only peer that counts binary `_batch` probes: refuses the
    /// binary content type pre-execution (400) the way an old gateway
    /// would, forwarding everything else to a real [`FaasGateway`].
    struct CountingJsonOnlyPeer {
        inner: FaasGateway,
        binary_probes: Arc<AtomicUsize>,
    }

    impl Handler for CountingJsonOnlyPeer {
        fn handle(&self, req: Request) -> Response {
            if req.headers.get("content-type").map(String::as_str)
                == Some(BATCH_BINARY_CONTENT_TYPE)
            {
                self.binary_probes.fetch_add(1, Ordering::SeqCst);
                return Response::bad_request("bad json: unexpected byte".to_string());
            }
            self.inner.handle(req)
        }
    }

    #[test]
    fn binary_refusal_cache_survives_pooled_connection_recycling() {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        let backend = Arc::new(FaasBackend::new(
            ResourceSpec::paper_edge("unused"),
            exec as Arc<dyn crate::cluster::faas::Executor>,
            Arc::new(RealClock::new()),
        ));
        let probes = Arc::new(AtomicUsize::new(0));
        let gw = CountingJsonOnlyPeer {
            inner: FaasGateway::new(Arc::clone(&backend)),
            binary_probes: Arc::clone(&probes),
        };
        // Short idle timeout so the server retires the pooled keep-alive
        // connection between batches.
        let opts =
            ServerOptions { idle_timeout: Duration::from_millis(100), ..ServerOptions::default() };
        let server = Server::bind_with(0, 2, Arc::new(gw) as Arc<dyn Handler>, opts).unwrap();
        let addr = server.addr();
        faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 1 << 20, 0, &[]).unwrap();

        let handle = HttpHandle::new(addr.clone(), "edgepwd", "", "", "", "");
        let calls = vec![BatchCall::new("echo", Bytes::from("hi"))];
        let results = handle.invoke_batch(&calls);
        assert_eq!(results[0].as_ref().unwrap().0, &b"hi"[..]);
        assert_eq!(probes.load(Ordering::SeqCst), 1, "one probe, then refusal cached");

        // Let the server close the idle connection: the pool's copy goes
        // stale and the next batch rides a brand-new connection.
        std::thread::sleep(Duration::from_millis(500));
        let results = handle.invoke_batch(&calls);
        assert_eq!(results[0].as_ref().unwrap().0, &b"hi"[..]);
        assert_eq!(
            probes.load(Ordering::SeqCst),
            1,
            "recycled pooled connection must not re-pay the binary probe"
        );
        assert!(server.connections_accepted() >= 2, "the first connection was retired");
    }

    fn echo_gateway() -> (Server, Arc<FaasBackend>) {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        let backend = Arc::new(FaasBackend::new(
            ResourceSpec::paper_edge("unused"),
            exec as Arc<dyn crate::cluster::faas::Executor>,
            Arc::new(RealClock::new()),
        ));
        let server = FaasGateway::serve(Arc::clone(&backend), 2).unwrap();
        (server, backend)
    }

    #[test]
    fn idempotent_verbs_retry_through_a_transient_refusal() {
        use crate::util::faults;
        let _g = faults::test_guard();
        let (server, backend) = echo_gateway();
        let addr = server.addr();
        faults::injector().install(21);
        faults::injector()
            .add_rule(faults::FaultRule::new(&addr, faults::FaultKind::ConnectRefused));

        // With retries off, the first refusal is final and typed.
        let no_retry = HttpHandle::new(addr.clone(), "edgepwd", "", "", "", "").with_budgets(
            VerbBudgets { retry: false, ..VerbBudgets::default() },
        );
        let err = no_retry.deploy("echo", "img/echo", 1 << 20, 0, &[]).unwrap_err();
        assert!(is_connectivity_error(&err), "refusal is connectivity evidence: {err:#}");
        assert_eq!(backend.list().len(), 0, "nothing deployed through the fault");

        // With retries on, the link heals mid-backoff and the verb lands.
        let handle = HttpHandle::new(addr.clone(), "edgepwd", "", "", "", "").with_budgets(
            VerbBudgets {
                retries: 20,
                backoff_base: Duration::from_millis(20),
                backoff_cap: Duration::from_millis(100),
                ..VerbBudgets::default()
            },
        );
        let healer = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(250));
                faults::injector().heal(&addr);
            })
        };
        handle.deploy("echo", "img/echo", 1 << 20, 0, &[]).expect("deploy after heal");
        healer.join().unwrap();
        faults::injector().clear();
        assert_eq!(backend.list(), vec!["echo".to_string()]);
    }

    #[test]
    fn batch_budget_derives_from_the_tightest_call_and_fails_fast() {
        use crate::util::faults;
        let _g = faults::test_guard();
        let (server, _backend) = echo_gateway();
        let addr = server.addr();
        faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 1 << 20, 0, &[]).unwrap();
        faults::injector().install(23);
        faults::injector().add_rule(faults::FaultRule::new(&addr, faults::FaultKind::BlackHole));

        let handle = HttpHandle::new(addr.clone(), "edgepwd", "", "", "", "").with_budgets(
            VerbBudgets {
                backoff_base: Duration::from_millis(10),
                backoff_cap: Duration::from_millis(20),
                ..VerbBudgets::default()
            },
        );
        let calls = vec![BatchCall {
            name: "echo".into(),
            payload: Bytes::from("hi"),
            attempt: 41,
            budget: Some(Duration::from_millis(200)),
        }];
        let t0 = std::time::Instant::now();
        let results = handle.invoke_batch(&calls);
        faults::injector().clear();
        assert!(results[0].is_err(), "black-holed batch fails");
        // Two 200 ms budgets (the at-most-once re-send) plus backoff —
        // nowhere near the 60 s default the per-call budget replaced.
        assert!(t0.elapsed() < Duration::from_secs(5), "failed at the budget: {:?}", t0.elapsed());
    }
}
