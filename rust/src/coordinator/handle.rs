//! The coordinator's view of a registered resource.
//!
//! EdgeFaaS only ever touches resources through their gateways — "EdgeFaaS
//! uses HTTP to request the RESTful APIs provided by the FaaS framework and
//! object store" (§3.1) — so the coordinator is written against this trait.
//! Two implementations:
//!
//! * [`LocalHandle`] — direct in-process calls into the cluster/objstore/
//!   monitor substrates. Used by the virtual-time benches (no sockets in the
//!   simulated hot loop) and by tests.
//! * [`HttpHandle`] — real loopback HTTP against the per-resource gateways,
//!   exactly the wire path the paper describes. Used by the examples.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cluster::faas::{FaasBackend, FunctionSpec};
pub use crate::cluster::faas::BatchCall;
use crate::cluster::gateway::client as faas_client;
use crate::monitor::metrics::ResourceUsage;
use crate::objstore::gateway::client as store_client;
use crate::objstore::ObjectStore;
use crate::util::bytes::Bytes;

/// Abstract per-resource operations the coordinator needs.
///
/// The data plane (`invoke` / `invoke_batch` / `put_object` / `get_object`)
/// moves shared [`Bytes`]: against a [`LocalHandle`] no payload is ever
/// copied (refcount bumps end to end); the [`HttpHandle`] copies exactly
/// once per direction at the wire.
pub trait ResourceHandle: Send + Sync {
    // ---- FaaS verbs (OpenFaaS gateway) ----
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()>;
    fn remove(&self, name: &str) -> anyhow::Result<()>;
    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)>;
    /// The backend protocol's `Batch` verb: invoke several functions in one
    /// gateway round trip, one result per entry. Each call carries its
    /// engine attempt id ([`BatchCall`]) so the backend can deduplicate
    /// liveness-plane retries at-most-once. The default implementation
    /// falls back to per-task [`ResourceHandle::invoke`] for backends that
    /// do not support batching (dropping dedup — acceptable for ad-hoc
    /// handles; the engine paths use [`LocalHandle`]/[`HttpHandle`]).
    fn invoke_batch(&self, calls: &[BatchCall]) -> Vec<anyhow::Result<(Bytes, f64)>> {
        calls.iter().map(|c| self.invoke(&c.name, &c.payload)).collect()
    }
    fn list(&self) -> anyhow::Result<Vec<String>>;
    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json>;

    // ---- monitoring (Prometheus) ----
    fn usage(&self) -> anyhow::Result<ResourceUsage>;

    // ---- storage verbs (MinIO) ----
    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()>;
    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()>;
    fn put_object(&self, bucket: &str, object: &str, data: Bytes) -> anyhow::Result<()>;
    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Bytes>;
    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()>;
    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>>;
    /// Total bytes stored (unregistration requires zero).
    fn stored_bytes(&self) -> anyhow::Result<u64>;
}

/// Direct in-process handle.
pub struct LocalHandle {
    pub backend: Arc<FaasBackend>,
    pub store: Arc<ObjectStore>,
}

impl LocalHandle {
    pub fn new(backend: Arc<FaasBackend>, store: Arc<ObjectStore>) -> Self {
        LocalHandle { backend, store }
    }
}

impl ResourceHandle for LocalHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        let labels: HashMap<String, String> = labels.iter().cloned().collect();
        self.backend
            .deploy(FunctionSpec {
                name: name.into(),
                image: std::sync::Arc::from(image),
                memory,
                gpus,
                labels,
            })
            .map_err(|e| anyhow::anyhow!(e))
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        self.backend.remove(name).map_err(|e| anyhow::anyhow!(e))
    }

    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        self.backend.invoke(name, payload)
    }

    fn invoke_batch(&self, calls: &[BatchCall]) -> Vec<anyhow::Result<(Bytes, f64)>> {
        self.backend.invoke_batch(calls)
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        Ok(self.backend.list())
    }

    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json> {
        let st = self.backend.describe(name).map_err(|e| anyhow::anyhow!(e))?;
        let mut o = crate::util::json::Json::obj();
        o.set("name", st.spec.name.as_str().into())
            .set("image", (&*st.spec.image).into())
            .set("replicas", (st.replicas as u64).into())
            .set("invocations", st.invocations.into())
            .set("url", st.url.as_str().into());
        Ok(o)
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        let spec = &self.backend.spec;
        Ok(ResourceUsage {
            cpu_frac: 0.0,
            mem_used: (self.backend.mem_utilization() * spec.total_memory() as f64) as u64,
            mem_total: spec.total_memory(),
            io_bytes_per_s: 0.0,
            gpu_frac: 0.0,
            gpus_used: 0,
            gpus_total: spec.total_gpus(),
        })
    }

    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        self.store.make_bucket(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        self.store.remove_bucket(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn put_object(&self, bucket: &str, object: &str, data: Bytes) -> anyhow::Result<()> {
        // Zero-copy: the shared buffer is moved into the store as-is.
        self.store.put_object(bucket, object, data).map_err(|e| anyhow::anyhow!(e))
    }

    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Bytes> {
        self.store.get_object(bucket, object).map_err(|e| anyhow::anyhow!(e))
    }

    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()> {
        self.store.remove_object(bucket, object).map_err(|e| anyhow::anyhow!(e))
    }

    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>> {
        self.store.list_objects(bucket).map_err(|e| anyhow::anyhow!(e))
    }

    fn stored_bytes(&self) -> anyhow::Result<u64> {
        Ok(self.store.used())
    }
}

/// Loopback-HTTP handle: the full REST wire path.
///
/// Construct with [`HttpHandle::new`]: the handle carries a private peer
/// capability cache alongside the address fields, so struct-literal
/// construction (possible in older revisions) no longer compiles.
pub struct HttpHandle {
    /// OpenFaaS-style gateway address (host:port).
    pub faas_addr: String,
    pub pwd: String,
    /// MinIO-style endpoint.
    pub minio_addr: String,
    pub access_key: String,
    pub secret_key: String,
    /// Prometheus endpoint ("" = no monitoring; usage() returns default).
    pub prometheus_addr: String,
    /// Peer capability cache: cleared the first time the gateway refuses
    /// the binary `_batch` frame format pre-execution (a JSON-only peer),
    /// so later batches skip the doomed binary round trip instead of
    /// shipping every payload twice.
    binary_batch_ok: std::sync::atomic::AtomicBool,
}

impl HttpHandle {
    pub fn new(
        faas_addr: impl Into<String>,
        pwd: impl Into<String>,
        minio_addr: impl Into<String>,
        access_key: impl Into<String>,
        secret_key: impl Into<String>,
        prometheus_addr: impl Into<String>,
    ) -> HttpHandle {
        HttpHandle {
            faas_addr: faas_addr.into(),
            pwd: pwd.into(),
            minio_addr: minio_addr.into(),
            access_key: access_key.into(),
            secret_key: secret_key.into(),
            prometheus_addr: prometheus_addr.into(),
            binary_batch_ok: std::sync::atomic::AtomicBool::new(true),
        }
    }
}

impl ResourceHandle for HttpHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        faas_client::deploy(&self.faas_addr, &self.pwd, name, image, memory, gpus, labels)
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        faas_client::remove(&self.faas_addr, &self.pwd, name)
    }

    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        // The client already returns a shared buffer (a window into the
        // HTTP response); no re-wrap copy.
        faas_client::invoke(&self.faas_addr, name, payload)
    }

    fn invoke_batch(&self, calls: &[BatchCall]) -> Vec<anyhow::Result<(Bytes, f64)>> {
        // One wire round trip: the length-prefixed binary frame format
        // (raw payloads/outputs — binary data travels at 1x instead of the
        // JSON leg's 2x hex), downgrading to the JSON format for old
        // peers. A peer's pre-execution refusal of the binary frames is
        // cached (`binary_batch_ok`), so a JSON-only gateway costs the
        // double round trip exactly once, not on every batch. Fallbacks
        // happen only when the batch verifiably did NOT execute
        // (`Refused` = pre-execution rejection); ambiguous failures —
        // transport/parse errors after the gateway may have executed the
        // batch — fail every entry instead of retrying, so non-idempotent
        // handlers never run twice.
        use crate::cluster::gateway::client::BatchAttempt;
        use std::sync::atomic::Ordering;
        let fail_all = |e: anyhow::Error| -> Vec<anyhow::Result<(Bytes, f64)>> {
            let msg = e.to_string();
            calls
                .iter()
                .map(|_| Err(anyhow::anyhow!("batch invoke failed: {}", msg.clone())))
                .collect()
        };
        if self.binary_batch_ok.load(Ordering::Relaxed) {
            match faas_client::invoke_batch_binary(&self.faas_addr, calls) {
                Ok(BatchAttempt::Ran(results)) => return results,
                Ok(BatchAttempt::Refused) => {
                    self.binary_batch_ok.store(false, Ordering::Relaxed);
                }
                Err(e) => return fail_all(e),
            }
        }
        match faas_client::invoke_batch_json(&self.faas_addr, calls) {
            Ok(BatchAttempt::Ran(results)) => results,
            // Both legs refused pre-execution (e.g. binary payloads
            // against a JSON-only peer): per-call invokes. The single-call
            // verb has no attempt field — dedup is lost on this legacy
            // path, exactly as for a pre-liveness peer.
            Ok(BatchAttempt::Refused) => {
                calls.iter().map(|c| self.invoke(&c.name, &c.payload)).collect()
            }
            Err(e) => fail_all(e),
        }
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        faas_client::list(&self.faas_addr)
    }

    fn describe(&self, name: &str) -> anyhow::Result<crate::util::json::Json> {
        faas_client::describe(&self.faas_addr, name)
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        if self.prometheus_addr.is_empty() {
            return Ok(ResourceUsage::default());
        }
        crate::monitor::scrape::scrape(&self.prometheus_addr)
    }

    fn make_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        store_client::make_bucket(&self.minio_addr, &self.access_key, &self.secret_key, bucket)
    }

    fn remove_bucket(&self, bucket: &str) -> anyhow::Result<()> {
        store_client::remove_bucket(&self.minio_addr, &self.access_key, &self.secret_key, bucket)
    }

    fn put_object(&self, bucket: &str, object: &str, data: Bytes) -> anyhow::Result<()> {
        store_client::put_object(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            object,
            &data,
        )
    }

    fn get_object(&self, bucket: &str, object: &str) -> anyhow::Result<Bytes> {
        store_client::get_object(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            object,
        )
    }

    fn remove_object(&self, bucket: &str, object: &str) -> anyhow::Result<()> {
        store_client::remove_object(
            &self.minio_addr,
            &self.access_key,
            &self.secret_key,
            bucket,
            object,
        )
    }

    fn list_objects(&self, bucket: &str) -> anyhow::Result<Vec<String>> {
        store_client::list_objects(&self.minio_addr, &self.access_key, &self.secret_key, bucket)
    }

    fn stored_bytes(&self) -> anyhow::Result<u64> {
        // Sum object sizes across buckets via the REST interface (rides a
        // pooled keep-alive connection like every other client call).
        let mut total = 0u64;
        let resp = crate::util::http::request(
            &self.minio_addr,
            "GET",
            "/buckets",
            &[("X-Access-Key", &self.access_key), ("X-Secret-Key", &self.secret_key)],
            &[],
        )?;
        if !resp.ok() {
            anyhow::bail!("list buckets: {}", resp.status);
        }
        let buckets: Vec<String> = resp
            .json_body()?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|b| b.as_str().map(String::from))
            .collect();
        for b in buckets {
            for o in self.list_objects(&b)? {
                total += self.get_object(&b, &o)?.len() as u64;
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::faas::NativeExecutor;
    use crate::cluster::gateway::{FaasGateway, BATCH_BINARY_CONTENT_TYPE};
    use crate::cluster::spec::ResourceSpec;
    use crate::simnet::RealClock;
    use crate::util::http::{Handler, Request, Response, Server, ServerOptions};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    /// A JSON-only peer that counts binary `_batch` probes: refuses the
    /// binary content type pre-execution (400) the way an old gateway
    /// would, forwarding everything else to a real [`FaasGateway`].
    struct CountingJsonOnlyPeer {
        inner: FaasGateway,
        binary_probes: Arc<AtomicUsize>,
    }

    impl Handler for CountingJsonOnlyPeer {
        fn handle(&self, req: Request) -> Response {
            if req.headers.get("content-type").map(String::as_str)
                == Some(BATCH_BINARY_CONTENT_TYPE)
            {
                self.binary_probes.fetch_add(1, Ordering::SeqCst);
                return Response::bad_request("bad json: unexpected byte".to_string());
            }
            self.inner.handle(req)
        }
    }

    #[test]
    fn binary_refusal_cache_survives_pooled_connection_recycling() {
        let exec = Arc::new(NativeExecutor::new());
        exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
        let backend = Arc::new(FaasBackend::new(
            ResourceSpec::paper_edge("unused"),
            exec as Arc<dyn crate::cluster::faas::Executor>,
            Arc::new(RealClock::new()),
        ));
        let probes = Arc::new(AtomicUsize::new(0));
        let gw = CountingJsonOnlyPeer {
            inner: FaasGateway::new(Arc::clone(&backend)),
            binary_probes: Arc::clone(&probes),
        };
        // Short idle timeout so the server retires the pooled keep-alive
        // connection between batches.
        let opts =
            ServerOptions { idle_timeout: Duration::from_millis(100), ..ServerOptions::default() };
        let server = Server::bind_with(0, 2, Arc::new(gw) as Arc<dyn Handler>, opts).unwrap();
        let addr = server.addr();
        faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 1 << 20, 0, &[]).unwrap();

        let handle = HttpHandle::new(addr.clone(), "edgepwd", "", "", "", "");
        let calls = vec![BatchCall::new("echo", Bytes::from("hi"))];
        let results = handle.invoke_batch(&calls);
        assert_eq!(results[0].as_ref().unwrap().0, &b"hi"[..]);
        assert_eq!(probes.load(Ordering::SeqCst), 1, "one probe, then refusal cached");

        // Let the server close the idle connection: the pool's copy goes
        // stale and the next batch rides a brand-new connection.
        std::thread::sleep(Duration::from_millis(500));
        let results = handle.invoke_batch(&calls);
        assert_eq!(results[0].as_ref().unwrap().0, &b"hi"[..]);
        assert_eq!(
            probes.load(Ordering::SeqCst),
            1,
            "recycled pooled connection must not re-pay the binary probe"
        );
        assert!(server.connections_accepted() >= 2, "the first connection was retired");
    }
}
