//! The event-driven execution engine (the dispatch core behind every
//! invocation front-end).
//!
//! The paper positions EdgeFaaS "in the critical-path, acting like a
//! router" for every invocation (§3.2.1). This module is that router's
//! execution core: a run table of in-flight workflow runs whose DAG nodes
//! fire as dependency-completion events, executed by a shared worker pool
//! under per-resource admission limits. Both invocation front-ends sit on
//! top of it:
//!
//! * synchronous [`EdgeFaaS::run_workflow`] = [`EdgeFaaS::submit_workflow`]
//!   + [`EdgeFaaS::wait_workflow`];
//! * asynchronous `invoke_async` = [`EdgeFaaS::spawn_job`] + tracker id
//!   (see [`super::asyncinvoke`]).
//!
//! The engine is generic over the [`crate::simnet::Clock`] the coordinator
//! was built with: under a `RealClock` the worker pool gives true wall-clock
//! parallelism; under a `VirtualClock` the same code path advances virtual
//! time (the benches' mode). Readiness is decided by dependency completion
//! with ready sets sorted by topological index, so chain-shaped DAGs (both
//! paper workflows) fire in the same order under either clock; independent
//! parallel branches may interleave by completion timing.
//!
//! # Sharding & wakeups
//!
//! Earlier revisions serialized every dispatch and completion through two
//! global mutexes (one ready queue, one run table) and broadcast every
//! state change over two global condvars — at 64+ concurrent runs the
//! locks, not the backends, were the bottleneck. The engine's mutable
//! state is now sharded so the hot path touches only per-shard locks:
//!
//! * **Per-resource dispatch queues.** Queued work lives in
//!   [`ENGINE_SHARDS`] dispatch shards, each its own mutex + condvar; an
//!   instance is routed to the shard of its placement's resource
//!   (`resource % active_shards`), so with the shard count at or above the
//!   resource count every resource has a private queue, and with
//!   [`EdgeFaaS::set_engine_shards`]`(1)` the engine collapses to the old
//!   single-lock behaviour (the bench baseline). Within a shard the QoS
//!   order is exactly the global rule below; across shards, workers pick
//!   shards best-class-first through the coordination set.
//!
//! * **Sharded run table.** Run bookkeeping lives in [`ENGINE_SHARDS`] hash
//!   shards keyed by run id, each with its own `done_cv`, so
//!   [`EdgeFaaS::wait_workflow`] callers and batched completion passes
//!   never contend — or thundering-herd — across unrelated runs: a run's
//!   completion notifies only the waiters parked on its own shard.
//!
//! * **Targeted wakeups via a small coordination struct.** When a shard
//!   gains dispatchable work it is *flagged* once — `(best class, flag
//!   seq, shard)` in a tiny ordered set guarded by a lock that protects a
//!   few integers, never task payloads — and exactly one worker is woken
//!   (or lazily spawned) per flag. An admission-slot release re-flags only
//!   the affected shard; nothing notifies every worker any more.
//!
//! * **Global invariants via atomics.** The pending-run count, the queued
//!   task/backlog counters behind backpressure, the Batch aging guard and
//!   the dispatch statistics are plain atomics, so submissions and
//!   completions consult them without any shared lock. The bounds are
//!   exact under sequential submission (what every test drives);
//!   concurrent submitters may transiently overshoot the per-resource
//!   queue bound by the number of racing threads.
//!
//! `set_engine_shards` must be called on an idle engine (no queued work,
//! no pending runs): shard routing of in-flight state is not rehashed.
//! Determinism is preserved across shard counts: a run's firing order and
//! outputs depend only on dependency completion and routing, which the
//! shard layout does not alter (verified by `rust/tests/shard_determinism.rs`
//! across shard counts {1, 4, 16} × both clocks × batching on/off).
//!
//! # Hot path & batching
//!
//! Two further optimizations keep per-invocation overhead flat:
//!
//! * **Zero-copy envelopes.** A node's invocation envelope is assembled at
//!   fire time, once per instance, into a shared [`Bytes`] buffer: the
//!   `{"app":...,"function":...` head is serialized exactly once per node
//!   and shared across all placements, and only the per-instance
//!   `inputs`/`resource` tail is appended per placement. Workers and the
//!   batch protocol clone refcounts, never payload bytes, and handler
//!   outputs travel back the same way.
//!
//! * **Per-resource invocation batching.** When a worker acquires a
//!   resource's admission slot it opportunistically drains other queued
//!   instances bound for the *same* resource — admission-deferred ones
//!   always, ready-queue ones only while the resource is saturated
//!   (draining below the admission limit would trade away parallelism an
//!   idle worker could provide) — up to [`DEFAULT_MAX_BATCH`] — and
//!   executes them as one batch: a single admission-slot acquisition, one
//!   backend `Batch` round trip
//!   ([`super::handle::ResourceHandle::invoke_batch`]; per-task fallback for
//!   backends without the verb), and one amortized completion pass that
//!   takes each affected run shard's lock twice per *batch* instead of
//!   twice per task. Because an instance's resource pins it to one shard,
//!   the whole drain happens under the single shard lock the worker
//!   already holds. A batch executes sequentially on one worker, so the
//!   per-resource concurrency bound is unchanged, and results fan back out
//!   to their runs in pop order — the exact order a lone worker would have
//!   produced — preserving the determinism guarantee (identical firing
//!   orders/outputs under `RealClock` and `VirtualClock`, batching on or
//!   off). Toggle with [`EdgeFaaS::set_batching`] /
//!   [`EdgeFaaS::set_max_batch`]; measured by
//!   `benches/ablation_concurrency.rs` (`BENCH_hotpath.json`,
//!   `BENCH_contention.json`).
//!
//! * **Adaptive dispatch window (off by default).** Under light load a
//!   freshly-acquired slot usually dispatches a batch of one. With
//!   [`EdgeFaaS::set_batch_window`] the slot holder parks on its shard's
//!   condvar for up to the window, waking early as same-shard enqueues
//!   arrive, then drains same-class same-resource ready work into the
//!   batch even when the resource is below its admission limit — trading
//!   bounded latency for fewer backend round trips.
//!
//! # QoS: ordering, deadlines, backpressure
//!
//! The paper claims EdgeFaaS "automatically optimizes the scheduling of
//! functions ... according to their performance and privacy requirements".
//! Every submission therefore carries a [`QoS`]: a [`Priority`] class
//! (`Realtime` > `Interactive` > `Batch`; default `Interactive`) and an
//! optional relative deadline in seconds.
//!
//! **Ordering rule.** Each shard's ready queue is a priority queue ordered
//! by the triple `(class, absolute deadline, submission sequence)`:
//! strictly by class first, earliest-deadline-first within a class (no
//! deadline sorts last), and a globally-assigned FIFO submission sequence
//! as the deterministic tie-break. Workers take flagged shards
//! best-class-first, so a `Realtime` instance dispatches before queued
//! `Interactive`/`Batch` work whether or not they share a shard.
//!
//! **Starvation guard (aging).** Strict priority alone would starve `Batch`
//! under sustained higher-class load, so the pop path ages the queue by
//! dispatch count (a global atomic): after [`BATCH_AGE_LIMIT`] consecutive
//! higher-class dispatches while `Batch` work waited anywhere, the oldest
//! dispatchable `Batch` task — workers prefer `Batch`-flagged shards while
//! the guard is tripped — runs next. Counting dispatches (not wall time)
//! keeps the guard identical under `RealClock` and `VirtualClock`.
//!
//! **Class-pure batching.** Per-resource invocation batching only coalesces
//! instances of the *same* class as the slot-holding instance: a `Batch`
//! run can never ride a slot acquired by a `Realtime` pop (and vice versa),
//! so batching cannot reorder work across classes.
//!
//! **Deadlines.** A run's deadline is fixed at submission
//! (`now + deadline_s`). Deadline enforcement happens at dispatch: an
//! instance popped after its run's deadline has passed is *not* executed —
//! the run transitions to [`RunStatus::DeadlineExceeded`], its remaining
//! queued instances drain without occupying backend slots, and
//! [`EngineEvent::DeadlineMissed`] fires so an [`EdgeFaaS::on_engine_event`]
//! policy (e.g. a reschedule hook) can react. Instances already executing
//! are never cancelled — a run whose work completes late still reports
//! `Done`.
//!
//! **Backpressure.** Two configurable bounds
//! ([`EdgeFaaS::set_backpressure`]): total pending (not-yet-finished) runs,
//! and queued instances per resource. A submission that would exceed either
//! bound is refused with [`EngineError::Saturated`] — the REST gateway maps
//! this to `429 Too Many Requests` with a `Retry-After` header — except
//! that a `Realtime`/`Interactive` submission first *sheds* queued
//! `Batch`-class runs (newest first, only runs with no instance currently
//! executing) to make room: under overload the coordinator degrades
//! predictably, Batch first, instead of queueing without bound.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use crate::util::bytes::Bytes;
use crate::util::json::Json;

use super::dag::RunState;
use super::handle::BatchCall;
use super::invoker::{parse_outputs, InstanceResult, WorkflowResult};
use super::resource::{Application, EdgeFaaS, ResourceId};

/// Identifier of one submitted workflow run.
pub type RunId = u64;

/// QoS class of a submission (see the module docs' ordering rule).
///
/// Classes are strict: all queued `Realtime` work dispatches before any
/// `Interactive` work, which dispatches before any `Batch` work — except
/// for the aging guard ([`BATCH_AGE_LIMIT`]) that keeps `Batch` from
/// starving under sustained higher-class load.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-critical: jumps every queue.
    Realtime,
    /// The default class for ordinary submissions.
    #[default]
    Interactive,
    /// Throughput-oriented: runs when nothing more urgent waits, is shed
    /// first under backpressure.
    Batch,
}

impl Priority {
    /// Ordering rank (lower dispatches first).
    pub(crate) const fn rank(self) -> u8 {
        match self {
            Priority::Realtime => 0,
            Priority::Interactive => 1,
            Priority::Batch => 2,
        }
    }

    /// Lowercase wire name (`realtime` / `interactive` / `batch`).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Realtime => "realtime",
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Priority {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Priority> {
        match s {
            "realtime" => Ok(Priority::Realtime),
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            other => Err(anyhow::anyhow!(
                "unknown priority `{other}` (expected realtime|interactive|batch)"
            )),
        }
    }
}

/// Per-submission quality-of-service requirements.
///
/// `deadline_s` is relative to submission time; the engine fixes the
/// absolute deadline at submit. Defaults: `Interactive`, no deadline.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QoS {
    pub priority: Priority,
    pub deadline_s: Option<f64>,
}

impl QoS {
    /// Shorthand for a class with no deadline.
    pub fn class(priority: Priority) -> QoS {
        QoS { priority, deadline_s: None }
    }

    /// Attach a relative deadline (seconds from submission).
    pub fn with_deadline(mut self, deadline_s: f64) -> QoS {
        self.deadline_s = Some(deadline_s);
        self
    }
}

/// Why a submission was not accepted by the engine.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// Backpressure: the configured queue bounds are reached and nothing
    /// Batch-class could be shed. The REST gateway maps this to
    /// `429 Too Many Requests` with a `Retry-After` header.
    Saturated {
        /// Pending (not yet finished) runs at rejection time.
        pending_runs: usize,
        /// The configured pending-run bound.
        max_pending_runs: usize,
        /// The resource whose queued-instance bound was the binding
        /// constraint, when it was a per-resource rejection.
        saturated_resource: Option<ResourceId>,
        /// Suggested client back-off, seconds.
        retry_after_s: f64,
    },
    /// The submission itself was invalid (unknown application, ...).
    Rejected(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Saturated {
                pending_runs,
                max_pending_runs,
                saturated_resource,
                retry_after_s,
            } => {
                write!(
                    f,
                    "engine saturated: {pending_runs}/{max_pending_runs} pending runs"
                )?;
                if let Some(rid) = saturated_resource {
                    write!(f, " (resource {rid} queue full)")?;
                }
                write!(f, "; retry after {retry_after_s:.0}s")
            }
            EngineError::Rejected(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for EngineError {}

/// Why [`EdgeFaaS::wait_workflow`] returned without a result. Each cause is
/// its own variant so callers can tell "the wait timed out but the run is
/// still in flight" from "the run itself failed" without parsing strings.
#[derive(Debug, Clone, PartialEq)]
pub enum WaitError {
    /// The wait's own timeout elapsed; the run is still executing (not
    /// failed) and can be waited on again.
    Timeout { run: RunId, waited_s: f64 },
    /// The run missed its QoS deadline ([`RunStatus::DeadlineExceeded`]).
    DeadlineExceeded { run: RunId },
    /// The run finished unsuccessfully.
    RunFailed { run: RunId, message: String },
    /// The run failed because a resource it depended on was declared dead
    /// by the liveness detector and no surviving candidate could take over
    /// its instances.
    ResourceDead { run: RunId, resource: ResourceId, message: String },
    /// No record of the run: never submitted, or already consumed.
    UnknownRun { run: RunId },
}

impl std::fmt::Display for WaitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WaitError::Timeout { run, waited_s } => write!(
                f,
                "timed out after {waited_s:.3}s waiting for workflow run {run} \
                 (the run is still executing, not failed)"
            ),
            WaitError::DeadlineExceeded { run } => {
                write!(f, "workflow run {run} exceeded its QoS deadline")
            }
            WaitError::RunFailed { run, message } => {
                write!(f, "workflow run {run} failed: {message}")
            }
            WaitError::ResourceDead { run, resource, message } => {
                write!(f, "workflow run {run} failed: resource {resource} died: {message}")
            }
            WaitError::UnknownRun { run } => write!(f, "unknown workflow run {run}"),
        }
    }
}

impl std::error::Error for WaitError {}

/// Externally visible state of a run.
#[derive(Debug, Clone)]
pub enum RunStatus {
    Running,
    Done(WorkflowResult),
    Failed(String),
    /// The run's QoS deadline passed before its queued work could
    /// dispatch; remaining instances were drained without executing.
    DeadlineExceeded,
}

/// A completion event published to [`EdgeFaaS::on_engine_event`] callbacks.
#[derive(Debug, Clone)]
pub enum EngineEvent {
    /// Every instance of one DAG node finished.
    NodeCompleted {
        run: RunId,
        app: String,
        function: String,
        /// Number of placement instances that executed.
        instances: usize,
        /// Slowest instance latency, seconds.
        latency: f64,
        /// Per-placement `(resource, latency)` pairs, in instance order —
        /// what load-driven policies (the auto-rescheduler's per-resource
        /// latency EWMA) consume.
        instance_latencies: Vec<(ResourceId, f64)>,
    },
    /// A whole run drained (successfully or not).
    RunCompleted { run: RunId, app: String, ok: bool, duration: f64 },
    /// A run's QoS deadline passed before its queued work could dispatch.
    /// Fires once per run, on the transition; reschedule policies
    /// subscribed via [`EdgeFaaS::on_engine_event`] can resubmit or
    /// migrate in response.
    DeadlineMissed {
        run: RunId,
        app: String,
        /// The configured relative deadline, seconds.
        deadline_s: f64,
        /// How far past the deadline the miss was detected, seconds.
        late_by: f64,
    },
    /// The liveness detector declared a resource Dead and its dispatch
    /// shard was drained. Fires after the drain, so candidate mappings and
    /// the monitor snapshot already exclude the resource when subscribers
    /// (e.g. relocation policies) observe it.
    ResourceDead {
        resource: ResourceId,
        /// Queued instances moved onto surviving candidates.
        queued_moved: usize,
        /// Queued instances whose runs failed typed (no survivor).
        queued_failed: usize,
    },
    /// A Dead resource answered scrapes through its quarantine and was
    /// re-admitted; its candidate memberships have been restored.
    ResourceRecovered { resource: ResourceId },
}

/// Typed refusal returned by [`EdgeFaaS::unregister`] when the resource
/// still has queued or in-flight engine work: yanking it would strand
/// those runs with no completion path (the historical hang). Names the
/// runs with queued instances so the caller can wait on them — or kill the
/// resource and let the liveness plane drain it.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceBusy {
    pub resource: ResourceId,
    /// Runs with instances queued on the resource (sorted, deduplicated).
    pub runs: Vec<RunId>,
    /// Instances queued (ready or admission-deferred) for the resource.
    pub queued: usize,
    /// Instances currently executing on the resource.
    pub in_flight: usize,
}

impl std::fmt::Display for ResourceBusy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "resource {} has {} queued and {} in-flight instance(s) (runs {:?}); wait for \
             them to finish, or let the liveness plane drain the resource",
            self.resource, self.queued, self.in_flight, self.runs
        )
    }
}

impl std::error::Error for ResourceBusy {}

/// A point-in-time snapshot of engine-wide counters
/// ([`EdgeFaaS::engine_stats`]; also served by the REST gateway's
/// `GET /engine/stats`).
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    /// Active shard count (dispatch queues and run-table shards).
    pub shards: usize,
    /// Runs admitted and not yet finished.
    pub pending_runs: usize,
    /// Instances currently queued (ready or admission-deferred).
    pub queued_instances: usize,
    /// Live worker threads / workers currently executing.
    pub workers: usize,
    pub busy_workers: usize,
    /// Backend dispatches (a batch counts once) / instances dispatched.
    pub batch_dispatches: u64,
    pub instances_dispatched: u64,
}

/// One schedulable unit: a single placement instance of a DAG node, or an
/// opaque job (the async-invoke front-end).
enum Task {
    Instance(InstanceTask),
    Job {
        class: Priority,
        /// Absolute deadline in integer nanoseconds (`u64::MAX` = none);
        /// for jobs this is an EDF ordering hint only — jobs are opaque and
        /// are never deadline-cancelled.
        deadline_ns: u64,
        job: Box<dyn FnOnce(&Arc<EdgeFaaS>) + Send + 'static>,
    },
}

impl Task {
    fn class(&self) -> Priority {
        match self {
            Task::Instance(t) => t.class,
            Task::Job { class, .. } => *class,
        }
    }

    fn deadline_ns(&self) -> u64 {
        match self {
            Task::Instance(t) => t.deadline_ns,
            Task::Job { deadline_ns, .. } => *deadline_ns,
        }
    }
}

struct InstanceTask {
    run: RunId,
    app: String,
    function: String,
    /// Index into the node's placement list.
    instance: usize,
    resource: ResourceId,
    /// The run's QoS class (queue ordering + class-pure batching).
    class: Priority,
    /// The run's absolute deadline in integer nanoseconds (`u64::MAX` =
    /// no deadline) — the EDF component of the queue key.
    deadline_ns: u64,
    /// Fully-assembled invocation envelope, built once at fire time (the
    /// node-common head is serialized once and shared across placements).
    /// Shared `Bytes`: the batch protocol clones refcounts, not payloads.
    envelope: Bytes,
    /// Globally unique attempt id (nonzero), threaded through the `_batch`
    /// wire so a backend can deduplicate a liveness retry whose first
    /// attempt actually executed on a half-dead resource. Preserved across
    /// drain re-anchoring and retries.
    attempt: u64,
    /// Set once the liveness path has retried this instance: in-flight
    /// work is retried at most once per node, never a second time.
    retried: bool,
}

/// One queued instance lent to a federated thief coordinator
/// (`POST /federation/steal`): the original task kept for bookkeeping —
/// the thief reports the outcome back and [`EdgeFaaS::complete_remote_instance`]
/// finishes the run exactly as a local completion would — plus the reclaim
/// deadline after which an unacknowledged loan is re-enqueued locally.
/// The attempt id travels with the loan, so a reclaim racing a slow thief
/// is deduplicated at the backend's attempt cache (at-most-once).
struct LentInstance {
    task: InstanceTask,
    /// Engine-clock time after which the loan is reclaimed.
    reclaim_at: f64,
}

/// A queued instance exported to a thief coordinator — the
/// `POST /federation/steal` wire payload (see [`super::federation`]).
/// Deadlines travel as *remaining* seconds, not absolute clock times, so
/// coordinators need not share a clock origin.
#[derive(Debug, Clone)]
pub struct StolenInstance {
    pub run: RunId,
    pub app: String,
    pub function: String,
    /// Index into the node's placement list (loan identity on the victim).
    pub instance: usize,
    /// The resource the victim had anchored the instance on.
    pub resource: ResourceId,
    pub class: Priority,
    /// Remaining deadline budget at export, seconds (`None` = no deadline).
    pub remaining_s: Option<f64>,
    /// The fire-time invocation envelope, verbatim.
    pub envelope: Bytes,
    /// The victim's attempt id, preserved so the backend's dedup cache
    /// covers thief execution racing a reclaim.
    pub attempt: u64,
    pub retried: bool,
}

/// Priority-queue key: strict class first, earliest deadline within the
/// class (`u64::MAX` = none, sorts last), then submission sequence for a
/// deterministic FIFO tie-break. Derived `Ord` is lexicographic over the
/// fields in this order. The sequence is assigned from one global atomic,
/// so the tie-break is identical at every shard count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct QKey {
    class: u8,
    deadline_ns: u64,
    seq: u64,
}

impl QKey {
    const MIN: QKey = QKey { class: 0, deadline_ns: 0, seq: 0 };

    /// Smallest key of the `Batch` class (the start of the aged range).
    const BATCH_MIN: QKey =
        QKey { class: Priority::Batch.rank(), deadline_ns: 0, seq: 0 };
}

/// Bookkeeping for one in-flight workflow run.
struct RunEntry {
    app_name: String,
    app: Arc<Application>,
    entry_inputs: HashMap<String, Vec<String>>,
    state: RunState,
    /// Nodes already fired (guards duplicate entrypoints).
    fired: HashSet<String>,
    /// Node -> instances still executing.
    pending: HashMap<String, usize>,
    /// Node -> per-instance results collected so far.
    partial: HashMap<String, Vec<Option<InstanceResult>>>,
    result: WorkflowResult,
    /// Tasks enqueued but not yet finished (0 = run drained).
    open_tasks: usize,
    started: f64,
    /// The QoS the run was submitted with.
    qos: QoS,
    /// Absolute deadline (clock seconds), fixed at submission.
    deadline_abs: Option<f64>,
    /// Set once when the deadline is detected as missed at dispatch.
    deadline_missed: bool,
    failed: Option<String>,
    /// When the failure was caused by a dead resource with no surviving
    /// candidate, the resource — [`WaitError::ResourceDead`]'s payload.
    dead_resource: Option<ResourceId>,
    done: bool,
}

/// Queue + admission state of one dispatch shard, under one lock so slot
/// acquisition and release cannot deadlock against the pop path. A
/// resource's instances all hash to one shard, so this is the per-resource
/// dispatch queue (shards may host several resources at low shard counts).
struct DispatchState {
    /// The QoS-ordered ready queue (see [`QKey`] for the ordering rule).
    ready: BTreeMap<QKey, Task>,
    /// Instances that were popped but found their resource at its admission
    /// limit; re-scanned (in the same QoS order) whenever a slot frees up.
    /// They keep their original key, so age/priority is preserved.
    deferred: BTreeMap<QKey, InstanceTask>,
    /// Resource -> instances currently executing on it.
    in_use: HashMap<ResourceId, usize>,
    /// The `(class rank, flag seq)` under which this shard is currently
    /// registered in the coordination set (None = unflagged). A flag means
    /// "a worker has been woken/spawned for this shard and has not yet
    /// arrived"; it is cleared by the arriving worker and re-raised
    /// whenever dispatchable work remains or appears.
    flag: Option<(u8, u64)>,
}

/// One dispatch shard: queue state + the shard's condvar. The condvar is
/// the adaptive-window parking spot — a slot holder waiting for its batch
/// to fill is woken by same-shard enqueues only.
struct DispatchShard {
    state: Mutex<DispatchState>,
    cv: Condvar,
}

/// One run-table shard: run map + completion retention + its own `done_cv`
/// so completion wakeups reach only waiters of runs hashed here.
struct RunShard {
    state: Mutex<RunShardState>,
    done_cv: Condvar,
}

struct RunShardState {
    map: HashMap<RunId, RunEntry>,
    /// Completed runs not yet consumed, oldest first. Bounded per shard by
    /// [`MAX_FINISHED_RUNS`] so submit-and-forget clients (e.g. a crashed
    /// REST poller) cannot grow the coordinator's memory without bound.
    finished: VecDeque<RunId>,
}

/// The small coordination struct: which shards have dispatchable work, and
/// the worker-pool accounting. Its critical sections touch a few integers
/// and a tiny ordered set — never task payloads — so it stays cold even
/// when every worker passes through it per dispatch.
struct CoordState {
    /// Flagged shards, ordered `(best class rank, flag seq, shard)` so a
    /// waking worker serves the most urgent shard first and FIFO breaks
    /// ties deterministically.
    flags: BTreeSet<(u8, u64, usize)>,
    next_flag: u64,
    /// Live worker threads.
    workers: usize,
    /// Workers currently serving a shard (the rest are parked or arriving).
    busy: usize,
}

struct Coord {
    state: Mutex<CoordState>,
    /// Idle workers park here; one `notify_one` per new flag.
    cv: Condvar,
}

/// Completed-but-unconsumed runs retained across the whole run table
/// before the oldest are evicted (the bound is split evenly across the
/// active run shards, so sharding does not multiply the memory a
/// submit-and-forget client can pin).
pub const MAX_FINISHED_RUNS: usize = 1024;

type EventCallback = Arc<dyn Fn(&EdgeFaaS, &EngineEvent) + Send + Sync>;

/// Physical shard count for both the dispatch queues and the run table.
/// [`EdgeFaaS::set_engine_shards`] activates a prefix `1..=ENGINE_SHARDS`
/// of them (default: all).
pub const ENGINE_SHARDS: usize = 16;

/// The shared execution core owned by [`EdgeFaaS`].
pub(super) struct EngineCore {
    next_run: AtomicU64,
    /// Global submission sequence — the deterministic FIFO tie-break,
    /// identical at every shard count.
    next_seq: AtomicU64,
    /// Per-instance attempt ids (nonzero; 0 on the wire = "no dedup").
    next_attempt: AtomicU64,
    max_workers: AtomicUsize,
    per_resource_slots: AtomicUsize,
    /// Largest per-resource invocation batch a worker may drain (1 =
    /// batching off: every instance dispatches individually).
    max_batch: AtomicUsize,
    /// Adaptive dispatch window, integer nanoseconds (0 = off).
    batch_window_ns: AtomicU64,
    /// Backpressure: total pending (not yet finished) runs admitted.
    max_pending_runs: AtomicUsize,
    /// Backpressure: queued instances allowed per resource.
    max_queued_per_resource: AtomicUsize,
    /// Lease-aware backpressure: registered resources and the subset whose
    /// lease is schedulable, maintained by the monitoring plane after each
    /// snapshot publish. While part of the fleet is Suspect/Dead, the
    /// pending-run bound scales down proportionally (0/0 = no lease
    /// information yet: the static bound applies unscaled).
    fleet_total: AtomicUsize,
    fleet_schedulable: AtomicUsize,
    /// Active shard prefix (1..=ENGINE_SHARDS).
    active_shards: AtomicUsize,
    /// Pending (admitted, not yet finished) runs — the pending-run
    /// backpressure bound compares against this.
    pending_runs: AtomicUsize,
    /// Instances queued (ready + deferred) across all shards.
    queued_instances: AtomicUsize,
    /// Jobs queued across all shards.
    queued_jobs: AtomicUsize,
    /// Batch-class tasks queued anywhere (the aging guard's "Batch work
    /// waited" condition, without scanning shards).
    queued_batch_class: AtomicUsize,
    /// Consecutive higher-class dispatches while Batch work waited (the
    /// aging counter; see [`BATCH_AGE_LIMIT`]).
    since_batch: AtomicU64,
    /// Dispatch statistics: backend dispatches (a batch counts once) and
    /// instances dispatched.
    batch_dispatches: AtomicU64,
    instances_dispatched: AtomicU64,
    /// Instances lent to federated thief coordinators, awaiting their
    /// completion report, keyed `(run, function, instance)`.
    lent: Mutex<HashMap<(RunId, String, usize), LentInstance>>,
    /// Loan counters: exported / completed remotely / returned unexecuted
    /// (requeued) / reclaimed after the loan deadline.
    instances_lent: AtomicU64,
    lent_completed: AtomicU64,
    lent_requeued: AtomicU64,
    lent_reclaimed: AtomicU64,
    dispatch: Vec<DispatchShard>,
    runs: Vec<RunShard>,
    coord: Coord,
    /// Event subscribers. Emitting clones the `Arc` under a read lock —
    /// never the callback list itself.
    callbacks: RwLock<Arc<[EventCallback]>>,
}

/// Default cap on worker threads (lazily spawned, exit when idle).
pub const DEFAULT_MAX_WORKERS: usize = 16;
/// Default concurrently-executing instances admitted per resource.
pub const DEFAULT_PER_RESOURCE_SLOTS: usize = 8;
/// Default cap on a per-resource invocation batch (see the module docs).
pub const DEFAULT_MAX_BATCH: usize = 16;
/// Default bound on pending (not yet finished) runs before
/// [`EngineError::Saturated`].
pub const DEFAULT_MAX_PENDING_RUNS: usize = 1024;
/// Default bound on queued instances per resource before
/// [`EngineError::Saturated`].
pub const DEFAULT_MAX_QUEUED_PER_RESOURCE: usize = 4096;
/// Aging guard: after this many consecutive higher-class instance
/// dispatches (popped or coalesced into a batching drain) while `Batch`
/// work waited, the oldest dispatchable `Batch` task runs next.
/// Dispatch-count based (not time based) so the guard behaves identically
/// under `RealClock` and `VirtualClock`.
pub const BATCH_AGE_LIMIT: u64 = 16;
/// `Retry-After` hint returned with [`EngineError::Saturated`], seconds.
pub const SATURATED_RETRY_AFTER_S: f64 = 1.0;

impl EngineCore {
    pub(super) fn new() -> EngineCore {
        let dispatch = (0..ENGINE_SHARDS)
            .map(|_| DispatchShard {
                state: Mutex::new(DispatchState {
                    ready: BTreeMap::new(),
                    deferred: BTreeMap::new(),
                    in_use: HashMap::new(),
                    flag: None,
                }),
                cv: Condvar::new(),
            })
            .collect();
        let runs = (0..ENGINE_SHARDS)
            .map(|_| RunShard {
                state: Mutex::new(RunShardState {
                    map: HashMap::new(),
                    finished: VecDeque::new(),
                }),
                done_cv: Condvar::new(),
            })
            .collect();
        EngineCore {
            next_run: AtomicU64::new(0),
            next_seq: AtomicU64::new(0),
            next_attempt: AtomicU64::new(1),
            max_workers: AtomicUsize::new(DEFAULT_MAX_WORKERS),
            per_resource_slots: AtomicUsize::new(DEFAULT_PER_RESOURCE_SLOTS),
            max_batch: AtomicUsize::new(DEFAULT_MAX_BATCH),
            batch_window_ns: AtomicU64::new(0),
            max_pending_runs: AtomicUsize::new(DEFAULT_MAX_PENDING_RUNS),
            max_queued_per_resource: AtomicUsize::new(DEFAULT_MAX_QUEUED_PER_RESOURCE),
            fleet_total: AtomicUsize::new(0),
            fleet_schedulable: AtomicUsize::new(0),
            active_shards: AtomicUsize::new(ENGINE_SHARDS),
            pending_runs: AtomicUsize::new(0),
            queued_instances: AtomicUsize::new(0),
            queued_jobs: AtomicUsize::new(0),
            queued_batch_class: AtomicUsize::new(0),
            since_batch: AtomicU64::new(0),
            batch_dispatches: AtomicU64::new(0),
            instances_dispatched: AtomicU64::new(0),
            lent: Mutex::new(HashMap::new()),
            instances_lent: AtomicU64::new(0),
            lent_completed: AtomicU64::new(0),
            lent_requeued: AtomicU64::new(0),
            lent_reclaimed: AtomicU64::new(0),
            dispatch,
            runs,
            coord: Coord {
                state: Mutex::new(CoordState {
                    flags: BTreeSet::new(),
                    next_flag: 0,
                    workers: 0,
                    busy: 0,
                }),
                cv: Condvar::new(),
            },
            callbacks: RwLock::new(Arc::from(Vec::<EventCallback>::new())),
        }
    }

    fn active(&self) -> usize {
        self.active_shards.load(Ordering::Relaxed).clamp(1, ENGINE_SHARDS)
    }

    /// Publish the fleet census for lease-aware admission (called by the
    /// monitoring plane after every snapshot publish, and by
    /// register/unregister).
    pub(super) fn set_fleet(&self, total: usize, schedulable: usize) {
        self.fleet_total.store(total, Ordering::Relaxed);
        self.fleet_schedulable.store(schedulable.min(total), Ordering::Relaxed);
    }

    fn dispatch_shard_of(&self, rid: ResourceId) -> usize {
        rid as usize % self.active()
    }

    fn run_shard_of(&self, run: RunId) -> usize {
        run as usize % self.active()
    }

    /// Queued (ready + admission-deferred) instances bound for one
    /// resource — the quantity the per-resource backpressure bound limits.
    /// Locks only the resource's own shard.
    fn queued_on(&self, rid: ResourceId) -> usize {
        let st = self.dispatch[self.dispatch_shard_of(rid)].state.lock().unwrap();
        let ready = st
            .ready
            .values()
            .filter(|t| matches!(t, Task::Instance(ti) if ti.resource == rid))
            .count();
        ready + st.deferred.values().filter(|t| t.resource == rid).count()
    }

    /// Register `sid` in the coordination set under `rank` (or upgrade an
    /// existing flag to a better rank). Caller holds the shard lock; the
    /// coord lock nests inside it (lock order: run shard → dispatch shard
    /// → coord). Returns true when the caller should spawn a worker.
    fn flag_shard_locked(&self, st: &mut DispatchState, sid: usize, rank: u8) -> bool {
        let mut c = self.coord.state.lock().unwrap();
        match st.flag {
            Some((r, s)) => {
                if rank < r {
                    let was_queued = c.flags.remove(&(r, s, sid));
                    let seq = c.next_flag;
                    c.next_flag += 1;
                    c.flags.insert((rank, seq, sid));
                    st.flag = Some((rank, seq));
                    if !was_queued {
                        // The old flag had already been claimed by an
                        // en-route worker, so this upgrade inserted a
                        // net-new flag: it needs its own wakeup/spawn, or
                        // a parked worker would sleep through claimable
                        // work until some busy worker loops back.
                        return self.wake_for_flag(&mut c);
                    }
                }
                false
            }
            None => {
                let seq = c.next_flag;
                c.next_flag += 1;
                c.flags.insert((rank, seq, sid));
                st.flag = Some((rank, seq));
                self.wake_for_flag(&mut c)
            }
        }
    }

    /// Targeted wakeup for one newly-inserted flag: notify exactly one
    /// parked worker, and tell the caller to spawn one when the flags
    /// outnumber the non-busy workers (caller holds the coord lock).
    fn wake_for_flag(&self, c: &mut CoordState) -> bool {
        self.coord.cv.notify_one();
        let max = self.max_workers.load(Ordering::Relaxed).max(1);
        if c.flags.len() > c.workers.saturating_sub(c.busy) && c.workers < max {
            c.workers += 1;
            true
        } else {
            false
        }
    }

    /// Pop the next task of this shard in QoS order, applying the global
    /// aging guard, and settle the global queued counters.
    fn pop_task(&self, st: &mut DispatchState, limit: usize) -> Option<Task> {
        let aged = if self.since_batch.load(Ordering::SeqCst) >= BATCH_AGE_LIMIT {
            pop_best(st, limit, QKey::BATCH_MIN)
        } else {
            None
        };
        let popped = aged.or_else(|| pop_best(st, limit, QKey::MIN))?;
        match &popped {
            Task::Instance(_) => {
                self.queued_instances.fetch_sub(1, Ordering::SeqCst);
            }
            Task::Job { .. } => {
                self.queued_jobs.fetch_sub(1, Ordering::SeqCst);
            }
        }
        if popped.class() == Priority::Batch {
            self.queued_batch_class.fetch_sub(1, Ordering::SeqCst);
            self.since_batch.store(0, Ordering::SeqCst);
        } else if self.queued_batch_class.load(Ordering::SeqCst) > 0 {
            self.since_batch.fetch_add(1, Ordering::SeqCst);
        } else {
            self.since_batch.store(0, Ordering::SeqCst);
        }
        Some(popped)
    }
}

/// Class rank of the best *dispatchable* task in a shard (None = nothing
/// can dispatch: empty, or only admission-blocked instances). Because the
/// key orders by class first, the first dispatchable entry in key order
/// has the minimal dispatchable class.
fn poppable_rank(st: &DispatchState, limit: usize) -> Option<u8> {
    let ready = st.ready.iter().find(|(_, t)| match t {
        Task::Job { .. } => true,
        Task::Instance(ti) => st.in_use.get(&ti.resource).copied().unwrap_or(0) < limit,
    });
    let deferred = st
        .deferred
        .iter()
        .find(|(_, t)| st.in_use.get(&t.resource).copied().unwrap_or(0) < limit);
    match (ready, deferred) {
        (None, None) => None,
        (Some((k, _)), None) => Some(k.class),
        (None, Some((k, _))) => Some(k.class),
        (Some((rk, _)), Some((dk, _))) => Some(rk.class.min(dk.class)),
    }
}

/// Take the best dispatchable task at or above `lo` in key order, merging
/// the ready queue and the admission-deferred set (both are QoS-ordered;
/// the globally smallest dispatchable key wins). Ready instances whose
/// resource is at its admission limit migrate to `deferred` under their
/// original key. Returns `None` when nothing in the range can dispatch.
fn pop_best(q: &mut DispatchState, limit: usize, lo: QKey) -> Option<Task> {
    loop {
        let d_key = {
            let in_use = &q.in_use;
            q.deferred
                .range(lo..)
                .find(|(_, t)| in_use.get(&t.resource).copied().unwrap_or(0) < limit)
                .map(|(k, _)| *k)
        };
        let r_key = q.ready.range(lo..).next().map(|(k, _)| *k);
        let take_ready = match (r_key, d_key) {
            (None, None) => return None,
            (Some(rk), Some(dk)) => rk < dk,
            (Some(_), None) => true,
            (None, Some(_)) => false,
        };
        if take_ready {
            let rk = r_key.expect("checked in take_ready");
            let task = q.ready.remove(&rk).expect("key just observed");
            match task {
                Task::Job { .. } => return Some(task),
                Task::Instance(t) => {
                    if q.in_use.get(&t.resource).copied().unwrap_or(0) < limit {
                        *q.in_use.entry(t.resource).or_insert(0) += 1;
                        return Some(Task::Instance(t));
                    }
                    q.deferred.insert(rk, t);
                }
            }
        } else {
            let dk = d_key.expect("checked in take_ready");
            let t = q.deferred.remove(&dk).expect("key just observed");
            *q.in_use.entry(t.resource).or_insert(0) += 1;
            return Some(Task::Instance(t));
        }
    }
}

/// Re-anchor a fire-time envelope on a different resource: the envelope's
/// trailing `"resource":<id>}` field (always last — see `fire_node`'s
/// serialization) is rewritten in place of re-serializing the whole JSON
/// tree. Falls back to the original envelope if the marker is missing
/// (malformed envelopes fail downstream either way).
pub(super) fn patch_envelope_resource(envelope: &Bytes, target: ResourceId) -> Bytes {
    let Ok(s) = std::str::from_utf8(envelope) else { return envelope.clone() };
    match s.rfind(",\"resource\":") {
        Some(pos) => {
            let mut out = String::with_capacity(pos + 24);
            out.push_str(&s[..pos]);
            out.push_str(",\"resource\":");
            out.push_str(&(target as u64).to_string());
            out.push('}');
            Bytes::from(out)
        }
        None => envelope.clone(),
    }
}

/// Remaining deadline budget of a task at `now` (engine-clock seconds), as
/// a client-side request budget for remote handles. `u64::MAX` (no run
/// deadline) carries `None` — the handle's default invoke budget applies.
/// Expired-but-dispatched tasks clamp to 1ns so the wire call fails fast
/// rather than inheriting a 60s default.
fn remaining_budget(deadline_ns: u64, now_s: f64) -> Option<std::time::Duration> {
    if deadline_ns == u64::MAX {
        return None;
    }
    let now_ns = (now_s.max(0.0) * 1e9) as u64;
    Some(std::time::Duration::from_nanos(deadline_ns.saturating_sub(now_ns).max(1)))
}

/// Execute one placement instance: call the resource gateway with the
/// prebuilt envelope and parse the outputs (the invoker's wire format).
///
/// A panicking function handler is caught and converted into an instance
/// error: letting it unwind through the worker would leak the admission
/// slot and busy/worker counts and leave the run's `open_tasks` stuck above
/// zero — wedging a synchronous `run_workflow` caller forever.
fn run_instance(faas: &EdgeFaaS, t: &InstanceTask) -> anyhow::Result<InstanceResult> {
    let invoked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> anyhow::Result<InstanceResult> {
            let reg = faas.resource(t.resource)?;
            let qname = EdgeFaaS::qualified(&t.app, &t.function);
            // Even a single instance goes through the batch verb so its
            // attempt id registers at the backend's dedup cache — the
            // at-most-once guarantee must cover first attempts, not only
            // batched ones.
            let calls = [BatchCall {
                name: qname,
                payload: t.envelope.clone(),
                attempt: t.attempt,
                budget: remaining_budget(t.deadline_ns, faas.clock.now()),
            }];
            let mut results = reg.handle.invoke_batch(&calls);
            anyhow::ensure!(
                results.len() == 1,
                "backend returned {} results for 1 call",
                results.len()
            );
            let (out, latency) = results.pop().expect("length checked")?;
            let outputs = parse_outputs(&out)?;
            Ok(InstanceResult { resource: t.resource, outputs, latency })
        },
    ));
    match invoked {
        Ok(result) => result,
        Err(payload) => {
            let what = crate::util::panic_message(&*payload);
            Err(anyhow::anyhow!("function handler panicked: {what}"))
        }
    }
}

/// Pull queued instances bound for `rid` *of the same QoS class as the
/// slot-holding instance* (admission-deferred first, then ready-queue
/// order; both in QoS key order) into `out`, up to `max_total` entries.
/// Shard-local: a resource's instances all live in one shard, so the whole
/// drain happens under the one shard lock the caller already holds. The
/// drained instances execute sequentially under the admission slot the
/// first instance already holds, so the per-resource concurrency bound is
/// preserved.
///
/// Class purity is a QoS invariant, not an optimization: a `Batch`
/// instance must never ride a slot acquired by a `Realtime` pop — it would
/// effectively jump every queue the ordering rule just made it wait in.
///
/// Ready-queue instances are drained only while the resource is saturated
/// (`in_use >= limit`) or when `force_ready` is set (the adaptive window's
/// final fill): below the limit, an idle worker could run them in
/// parallel, and pulling them into this batch would trade that parallelism
/// away. Deferred instances are admission-blocked either way, so joining
/// the batch never costs them anything.
#[allow(clippy::too_many_arguments)]
fn drain_same_resource(
    eng: &EngineCore,
    q: &mut DispatchState,
    rid: ResourceId,
    class: Priority,
    limit: usize,
    max_total: usize,
    force_ready: bool,
    out: &mut Vec<InstanceTask>,
) {
    // No coalescing while a *higher*-class instance waits for this same
    // resource: it is entitled to the slot at the next release, and a
    // drained batch would run up to max_batch lower-class instances ahead
    // of it — a priority inversion the ordering rule forbids. (`..lim` is
    // exactly the keys of strictly higher classes.)
    let lim = QKey { class: class.rank(), deadline_ns: 0, seq: 0 };
    let higher_waits = q
        .ready
        .range(..lim)
        .any(|(_, t)| matches!(t, Task::Instance(ti) if ti.resource == rid))
        || q.deferred.range(..lim).any(|(_, t)| t.resource == rid);
    if higher_waits {
        return;
    }
    let before = out.len();
    let keys: Vec<QKey> = q
        .deferred
        .iter()
        .filter(|(k, t)| k.class == class.rank() && t.resource == rid)
        .map(|(k, _)| *k)
        .take(max_total.saturating_sub(out.len()))
        .collect();
    for k in keys {
        out.push(q.deferred.remove(&k).expect("key just collected"));
    }
    if force_ready || q.in_use.get(&rid).copied().unwrap_or(0) >= limit {
        let keys: Vec<QKey> = q
            .ready
            .iter()
            .filter(|(k, t)| {
                k.class == class.rank()
                    && matches!(t, Task::Instance(ti) if ti.resource == rid)
            })
            .map(|(k, _)| *k)
            .take(max_total.saturating_sub(out.len()))
            .collect();
        for k in keys {
            match q.ready.remove(&k) {
                Some(Task::Instance(t)) => out.push(t),
                _ => unreachable!("collected an instance key"),
            }
        }
    }
    // Settle the global counters for every drained task, and count each
    // drained higher-class instance toward the starvation bound exactly
    // like a popped one — otherwise batching would inflate the documented
    // [`BATCH_AGE_LIMIT`] by up to max_batch x.
    let drained = (out.len() - before) as u64;
    if drained == 0 {
        return;
    }
    eng.queued_instances.fetch_sub(drained as usize, Ordering::SeqCst);
    if class == Priority::Batch {
        eng.queued_batch_class.fetch_sub(drained as usize, Ordering::SeqCst);
    } else if eng.queued_batch_class.load(Ordering::SeqCst) > 0 {
        eng.since_batch.fetch_add(drained, Ordering::SeqCst);
    }
}

fn engine_worker(faas: Arc<EdgeFaaS>) {
    let eng = &faas.engine;
    loop {
        // Acquire a flagged shard: best class first, FIFO within a class;
        // once the aging guard trips, a Batch-flagged shard goes first.
        let taken = {
            let mut c = eng.coord.state.lock().unwrap();
            loop {
                let aged = if eng.since_batch.load(Ordering::SeqCst) >= BATCH_AGE_LIMIT {
                    c.flags.range((Priority::Batch.rank(), 0, 0)..).next().copied()
                } else {
                    None
                };
                let key = aged.or_else(|| c.flags.iter().next().copied());
                if let Some(k) = key {
                    c.flags.remove(&k);
                    c.busy += 1;
                    break Some(k);
                }
                // Nothing flagged. Exit when the whole engine is idle;
                // otherwise only admission-blocked work remains and the
                // releasing worker will flag its shard — park until then.
                if eng.queued_instances.load(Ordering::SeqCst) == 0
                    && eng.queued_jobs.load(Ordering::SeqCst) == 0
                {
                    c.workers -= 1;
                    break None;
                }
                c = eng.coord.cv.wait(c).unwrap();
            }
        };
        let Some((_rank, fseq, sid)) = taken else { return };
        serve_shard(&faas, sid, fseq);
        let mut c = eng.coord.state.lock().unwrap();
        c.busy -= 1;
    }
}

/// What a worker found when it arrived at a flagged shard.
enum Work {
    /// Stale flag: the work was drained/shed/stolen before arrival.
    None,
    Job(Box<dyn FnOnce(&Arc<EdgeFaaS>) + Send + 'static>),
    /// A same-resource batch holding one admission slot on the resource.
    Batch(ResourceId, Vec<InstanceTask>),
}

/// Serve one flag: pop the shard's best task (plus a same-resource batch
/// drain), re-flag the shard while more work is dispatchable so other
/// workers can serve it in parallel, execute, then release the admission
/// slot — flagging again if the release unblocked deferred work.
fn serve_shard(faas: &Arc<EdgeFaaS>, sid: usize, fseq: u64) {
    let eng = &faas.engine;
    let shard = &eng.dispatch[sid];
    let limit = eng.per_resource_slots.load(Ordering::Relaxed).max(1);
    let max_batch = eng.max_batch.load(Ordering::Relaxed).max(1);
    let mut spawn = false;
    let work = {
        let mut st = shard.state.lock().unwrap();
        if matches!(st.flag, Some((_, s)) if s == fseq) {
            st.flag = None;
        }
        let work = match eng.pop_task(&mut st, limit) {
            None => Work::None,
            Some(Task::Job { job, .. }) => Work::Job(job),
            Some(Task::Instance(first)) => {
                let rid = first.resource;
                let class = first.class;
                let mut tasks = vec![first];
                if max_batch > 1 {
                    drain_same_resource(
                        eng, &mut st, rid, class, limit, max_batch, false, &mut tasks,
                    );
                }
                Work::Batch(rid, tasks)
            }
        };
        if let Some(rank) = poppable_rank(&st, limit) {
            spawn = eng.flag_shard_locked(&mut st, sid, rank);
        }
        work
    };
    if spawn {
        faas.spawn_worker();
    }
    match work {
        Work::None => {}
        Work::Job(job) => {
            // Same containment as run_instance: a panicking job must not
            // kill the worker and leak the busy/worker counts.
            let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(faas)));
            if ran.is_err() {
                log::warn!("engine job panicked; worker kept alive");
            }
            // Mirror complete_batch's idle wakeup: if this job drained the
            // engine, parked workers must re-evaluate and exit rather than
            // linger as live threads (job-only workloads never pass
            // through complete_batch).
            if eng.queued_instances.load(Ordering::SeqCst) == 0
                && eng.queued_jobs.load(Ordering::SeqCst) == 0
            {
                eng.coord.cv.notify_all();
            }
        }
        Work::Batch(rid, mut tasks) => {
            // Adaptive dispatch window: hold the acquired slot briefly so a
            // batch can fill under light load. The holder parks on the
            // *shard's* condvar (same-shard enqueues notify it), re-drains
            // on every wakeup, and force-drains ready work even below the
            // admission limit. The window is bounded by a *wall-clock*
            // deadline: a virtual clock's now() does not advance while we
            // wait, and unrelated same-shard enqueue wakeups must not
            // restart the wait, so only an Instant makes termination
            // unconditional.
            let window_ns = eng.batch_window_ns.load(Ordering::Relaxed);
            if window_ns > 0 && max_batch > 1 && tasks.len() < max_batch {
                let class = tasks[0].class;
                let wall_deadline = std::time::Instant::now()
                    + std::time::Duration::from_nanos(window_ns);
                let mut st = shard.state.lock().unwrap();
                loop {
                    drain_same_resource(
                        eng, &mut st, rid, class, limit, max_batch, true, &mut tasks,
                    );
                    if tasks.len() >= max_batch {
                        break;
                    }
                    let now = std::time::Instant::now();
                    if now >= wall_deadline {
                        break;
                    }
                    let (g, _timeout) =
                        shard.cv.wait_timeout(st, wall_deadline - now).unwrap();
                    st = g;
                    // Loop re-drains; the shrinking wall deadline bounds the
                    // total hold regardless of wakeup frequency.
                }
            }
            faas.run_batch(rid, tasks);
            // Release the admission slot; if that unblocked deferred work
            // (or ready work was waiting on this slot), flag the shard.
            let mut spawn2 = false;
            {
                let mut st = shard.state.lock().unwrap();
                if let Some(n) = st.in_use.get_mut(&rid) {
                    *n = n.saturating_sub(1);
                    if *n == 0 {
                        st.in_use.remove(&rid);
                    }
                }
                if let Some(rank) = poppable_rank(&st, limit) {
                    spawn2 = eng.flag_shard_locked(&mut st, sid, rank);
                }
            }
            if spawn2 {
                faas.spawn_worker();
            }
        }
    }
}

impl EdgeFaaS {
    /// Submit a workflow run with default QoS (`Interactive`, no deadline);
    /// returns immediately with its [`RunId`]. Entry functions fire at
    /// once; dependents fire as their dependencies complete, interleaved
    /// with every other in-flight run. See [`Self::submit_workflow_qos`]
    /// for the admission (backpressure) rules.
    pub fn submit_workflow(
        self: &Arc<Self>,
        app: &str,
        entry_inputs: &HashMap<String, Vec<String>>,
    ) -> Result<RunId, EngineError> {
        self.submit_workflow_qos(app, entry_inputs, QoS::default())
    }

    /// Submit a workflow run under an explicit [`QoS`].
    ///
    /// Admission control: if the pending-run bound or any entry resource's
    /// queued-instance bound ([`Self::set_backpressure`]) would be
    /// exceeded, `Realtime`/`Interactive` submissions first shed queued
    /// `Batch`-class runs (newest first, only runs with no instance
    /// currently executing; each shed run fails with a "shed under
    /// backpressure" message and publishes `RunCompleted { ok: false }`).
    /// If nothing can be shed — or the submission is itself `Batch` — the
    /// submission is refused with [`EngineError::Saturated`].
    ///
    /// The bounds are enforced through atomics (a CAS admits against the
    /// pending-run bound), so admission takes no engine-wide lock; under
    /// *concurrent* submission the per-resource bound may transiently
    /// overshoot by the number of racing submitters.
    pub fn submit_workflow_qos(
        self: &Arc<Self>,
        app: &str,
        entry_inputs: &HashMap<String, Vec<String>>,
        qos: QoS,
    ) -> Result<RunId, EngineError> {
        let application = self.app(app).map_err(|e| EngineError::Rejected(e.to_string()))?;
        let eng = &self.engine;
        // Entry-instance demand per resource (for the per-resource queue
        // bound). Placement errors are deliberately ignored here: such a
        // run is admitted and then fails through the normal fire path.
        let mut demand: HashMap<ResourceId, usize> = HashMap::new();
        for f in &application.config.entrypoints {
            for rid in self.candidates_of(app, f).unwrap_or_default() {
                *demand.entry(rid).or_insert(0) += 1;
            }
        }
        // Lease-aware backpressure: while part of the fleet is
        // unschedulable (Suspect/Dead/Recovering leases), the pending-run
        // bound scales with the surviving fraction — the shrunken fleet
        // cannot absorb the full bound, so shedding (Batch first, via the
        // loop below) engages early instead of queues deepening toward
        // partitioned resources. 0/0 means the monitoring plane has not
        // published a census yet; the static bound applies unscaled.
        let base_max_runs = eng.max_pending_runs.load(Ordering::Relaxed).max(1);
        let fleet_total = eng.fleet_total.load(Ordering::Relaxed);
        let fleet_sched = eng.fleet_schedulable.load(Ordering::Relaxed);
        let max_runs = if fleet_total > 0 && fleet_sched < fleet_total {
            (base_max_runs * fleet_sched / fleet_total).max(1)
        } else {
            base_max_runs
        };
        let max_queued = eng.max_queued_per_resource.load(Ordering::Relaxed).max(1);
        let mut events = Vec::new();
        let mut notify_shards: Vec<usize> = Vec::new();
        let admission: Result<(), EngineError> = loop {
            let pending = eng.pending_runs.load(Ordering::SeqCst);
            let saturated_resource = {
                // Fast path: if every queued task plus this run's largest
                // per-resource demand fits the bound, no single resource
                // can exceed it — skip the per-shard scans.
                let total_queued = eng.queued_instances.load(Ordering::SeqCst)
                    + eng.queued_jobs.load(Ordering::SeqCst);
                let max_demand = demand.values().copied().max().unwrap_or(0);
                if total_queued + max_demand <= max_queued {
                    None
                } else {
                    demand
                        .iter()
                        .find(|(rid, d)| eng.queued_on(**rid) + **d > max_queued)
                        .map(|(rid, _)| *rid)
                }
            };
            if pending < max_runs && saturated_resource.is_none() {
                if eng
                    .pending_runs
                    .compare_exchange(pending, pending + 1, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    break Ok(());
                }
                continue; // lost the CAS race: re-evaluate
            }
            // Shed only when it can actually relieve the binding
            // constraint: against the pending-run bound any queued Batch
            // run helps; against a saturated resource only Batch runs
            // queued *on that resource* do. A demand larger than the
            // per-resource bound can never be admitted, so nothing is shed
            // for it.
            let impossible = demand.values().any(|d| *d > max_queued);
            let shed_target = if pending >= max_runs { None } else { saturated_resource };
            if !impossible
                && qos.priority != Priority::Batch
                && self.shed_newest_queued_batch(shed_target, &mut events, &mut notify_shards)
            {
                continue;
            }
            break Err(EngineError::Saturated {
                pending_runs: pending,
                max_pending_runs: max_runs,
                saturated_resource,
                retry_after_s: SATURATED_RETRY_AFTER_S,
            });
        };
        let admitted = match admission {
            Err(e) => Err(e),
            Ok(()) => {
                let run = eng.next_run.fetch_add(1, Ordering::SeqCst);
                let now = self.clock.now();
                let entry = RunEntry {
                    app_name: app.to_string(),
                    app: Arc::clone(&application),
                    entry_inputs: entry_inputs.clone(),
                    state: RunState::new(&application.dag),
                    fired: HashSet::new(),
                    pending: HashMap::new(),
                    partial: HashMap::new(),
                    result: WorkflowResult::default(),
                    open_tasks: 0,
                    started: now,
                    qos,
                    deadline_abs: qos.deadline_s.map(|d| now + d.max(0.0)),
                    deadline_missed: false,
                    failed: None,
                    dead_resource: None,
                    done: false,
                };
                let sid = eng.run_shard_of(run);
                let mut batch = Vec::new();
                let completed = {
                    let mut rs = eng.runs[sid].state.lock().unwrap();
                    // Insert before enqueueing so a fast worker finds it.
                    rs.map.insert(run, entry);
                    let entry = rs.map.get_mut(&run).expect("just inserted");
                    let entrypoints = application.config.entrypoints.clone();
                    for f in &entrypoints {
                        if let Err(e) = self.fire_node(run, entry, f, &mut batch) {
                            entry.failed.get_or_insert(e.to_string());
                            break;
                        }
                    }
                    let completed = self.check_done(run, entry, &mut events);
                    if completed {
                        Self::retire_finished(eng, &mut rs, run);
                    }
                    completed
                };
                // Enqueue outside the run-shard lock: the entry is already
                // visible to any worker that races us to completion.
                self.enqueue(batch);
                if completed {
                    notify_shards.push(sid);
                }
                Ok(run)
            }
        };
        // Shed victims (and instantly-failed submissions) may already have
        // wait_workflow callers parked on their run shard.
        for sid in notify_shards {
            eng.runs[sid].done_cv.notify_all();
        }
        self.emit_events(&events);
        admitted
    }

    /// Shed the newest `Batch`-class run that has no instance currently
    /// executing: its queued instances are removed from the dispatch
    /// shards and the run fails with a backpressure message. With
    /// `on_resource` set, only runs with at least one instance queued on
    /// that resource qualify — shedding a run that cannot relieve the
    /// saturated resource would destroy it for zero benefit. Returns false
    /// when no run qualifies. Shards are scanned one lock at a time; a
    /// worker racing the scan is tolerated (a shed run's instance that
    /// slipped into execution completes against the already-failed run, a
    /// no-op).
    fn shed_newest_queued_batch(
        &self,
        on_resource: Option<ResourceId>,
        events: &mut Vec<EngineEvent>,
        notify_shards: &mut Vec<usize>,
    ) -> bool {
        let eng = &self.engine;
        let active = eng.active();
        let mut queued_per_run: HashMap<RunId, usize> = HashMap::new();
        let mut on_rid: HashSet<RunId> = HashSet::new();
        for sid in 0..active {
            let st = eng.dispatch[sid].state.lock().unwrap();
            for t in st.ready.values() {
                if let Task::Instance(ti) = t {
                    *queued_per_run.entry(ti.run).or_insert(0) += 1;
                    if Some(ti.resource) == on_resource {
                        on_rid.insert(ti.run);
                    }
                }
            }
            for t in st.deferred.values() {
                *queued_per_run.entry(t.run).or_insert(0) += 1;
                if Some(t.resource) == on_resource {
                    on_rid.insert(t.run);
                }
            }
        }
        let mut victim: Option<RunId> = None;
        for sid in 0..active {
            let rs = eng.runs[sid].state.lock().unwrap();
            for (id, e) in rs.map.iter() {
                if !e.done
                    && e.qos.priority == Priority::Batch
                    && e.open_tasks > 0
                    && queued_per_run.get(id).copied().unwrap_or(0) == e.open_tasks
                    && (on_resource.is_none() || on_rid.contains(id))
                {
                    victim = victim.max(Some(*id));
                }
            }
        }
        let Some(victim) = victim else { return false };
        // Remove the victim's queued tasks shard by shard, settling the
        // global counters (a Batch-class run's tasks are all Batch).
        for sid in 0..active {
            let mut st = eng.dispatch[sid].state.lock().unwrap();
            let keys: Vec<QKey> = st
                .ready
                .iter()
                .filter(|(_, t)| matches!(t, Task::Instance(ti) if ti.run == victim))
                .map(|(k, _)| *k)
                .collect();
            for k in keys {
                if st.ready.remove(&k).is_some() {
                    eng.queued_instances.fetch_sub(1, Ordering::SeqCst);
                    eng.queued_batch_class.fetch_sub(1, Ordering::SeqCst);
                }
            }
            let keys: Vec<QKey> =
                st.deferred.iter().filter(|(_, t)| t.run == victim).map(|(k, _)| *k).collect();
            for k in keys {
                if st.deferred.remove(&k).is_some() {
                    eng.queued_instances.fetch_sub(1, Ordering::SeqCst);
                    eng.queued_batch_class.fetch_sub(1, Ordering::SeqCst);
                }
            }
        }
        let rsid = eng.run_shard_of(victim);
        {
            let mut rs = eng.runs[rsid].state.lock().unwrap();
            if let Some(entry) = rs.map.get_mut(&victim) {
                entry.open_tasks = 0;
                entry.failed.get_or_insert_with(|| {
                    "shed under backpressure (batch-class run evicted by a higher-priority \
                     submission)"
                        .to_string()
                });
                log::warn!("engine saturated: shedding batch-class run {victim}");
                if self.check_done(victim, entry, events) {
                    Self::retire_finished(eng, &mut rs, victim);
                    notify_shards.push(rsid);
                }
            }
        }
        // A worker parked on the coordination condvar may have been
        // waiting for exactly the tasks just removed: wake the pool to
        // re-evaluate (idle workers exit).
        eng.coord.cv.notify_all();
        true
    }

    /// Block until a run completes (or `timeout_s` elapses; pass
    /// `f64::INFINITY` to wait forever). Consumes the run's record on
    /// completion. Each failure mode is a distinct [`WaitError`] variant:
    /// a wait timeout (the run is still executing and can be waited on
    /// again) is not a run failure, and a missed QoS deadline is reported
    /// as [`WaitError::DeadlineExceeded`] rather than a generic failure
    /// string. The wait parks on the run's own shard condvar, so
    /// completions of unrelated runs never wake it.
    pub fn wait_workflow(&self, run: RunId, timeout_s: f64) -> Result<WorkflowResult, WaitError> {
        let deadline = if timeout_s.is_finite() {
            Some(
                std::time::Instant::now()
                    + std::time::Duration::from_secs_f64(timeout_s.max(0.0)),
            )
        } else {
            None
        };
        let shard = &self.engine.runs[self.engine.run_shard_of(run)];
        let mut rs = shard.state.lock().unwrap();
        loop {
            let done = match rs.map.get(&run) {
                None => return Err(WaitError::UnknownRun { run }),
                Some(e) => e.done,
            };
            if done {
                let entry = rs.map.remove(&run).expect("checked above");
                if entry.deadline_missed {
                    return Err(WaitError::DeadlineExceeded { run });
                }
                return match entry.failed {
                    Some(message) => match entry.dead_resource {
                        Some(resource) => {
                            Err(WaitError::ResourceDead { run, resource, message })
                        }
                        None => Err(WaitError::RunFailed { run, message }),
                    },
                    None => Ok(entry.result),
                };
            }
            match deadline {
                None => rs = shard.done_cv.wait(rs).unwrap(),
                Some(d) => {
                    let now = std::time::Instant::now();
                    if now >= d {
                        return Err(WaitError::Timeout { run, waited_s: timeout_s.max(0.0) });
                    }
                    let (g, _) = shard.done_cv.wait_timeout(rs, d - now).unwrap();
                    rs = g;
                }
            }
        }
    }

    /// Non-blocking peek at a run (None once consumed by `wait_workflow` /
    /// `take_run`).
    pub fn run_status(&self, run: RunId) -> Option<RunStatus> {
        let rs = self.engine.runs[self.engine.run_shard_of(run)].state.lock().unwrap();
        rs.map.get(&run).map(Self::status_of)
    }

    /// Like [`Self::run_status`], but removes the record once the run is
    /// done (the REST gateway's poll-then-forget semantics).
    pub fn take_run(&self, run: RunId) -> Option<RunStatus> {
        let mut rs = self.engine.runs[self.engine.run_shard_of(run)].state.lock().unwrap();
        let done = rs.map.get(&run)?.done;
        if !done {
            return Some(RunStatus::Running);
        }
        let entry = rs.map.remove(&run).expect("checked above");
        Some(if entry.deadline_missed {
            RunStatus::DeadlineExceeded
        } else if let Some(msg) = entry.failed {
            RunStatus::Failed(msg)
        } else {
            RunStatus::Done(entry.result)
        })
    }

    fn status_of(e: &RunEntry) -> RunStatus {
        if !e.done {
            RunStatus::Running
        } else if e.deadline_missed {
            RunStatus::DeadlineExceeded
        } else if let Some(msg) = &e.failed {
            RunStatus::Failed(msg.clone())
        } else {
            RunStatus::Done(e.result.clone())
        }
    }

    /// QoS class and deadline state of a run still in the table: the
    /// submitted [`QoS`] plus, when a deadline was set, the remaining
    /// budget in seconds (negative once past). `None` once the record has
    /// been consumed.
    pub fn run_qos(&self, run: RunId) -> Option<(QoS, Option<f64>)> {
        let rs = self.engine.runs[self.engine.run_shard_of(run)].state.lock().unwrap();
        rs.map
            .get(&run)
            .map(|e| (e.qos, e.deadline_abs.map(|d| d - self.clock.now())))
    }

    /// Run an opaque job on the engine's worker pool (the async-invoke
    /// front-end; also usable for background coordinator chores).
    ///
    /// Jobs may themselves block on further engine progress (a nested
    /// `invoke_async`, a `run_workflow` issued from a background chore), so
    /// unlike instances they are never allowed to deadlock against the
    /// worker cap: when no free worker exists at submission time, one
    /// worker is spawned past `max_workers` — bounded by one thread per
    /// outstanding job, the same bound the old thread-per-async-invocation
    /// design had.
    pub fn spawn_job(self: &Arc<Self>, job: impl FnOnce(&Arc<EdgeFaaS>) + Send + 'static) {
        self.spawn_job_qos(QoS::default(), job)
    }

    /// [`Self::spawn_job`] under an explicit [`QoS`]: the class orders the
    /// job against every other queued task, and a deadline (if any) is an
    /// EDF ordering hint — jobs are opaque, so they are never
    /// deadline-cancelled and are not subject to run backpressure.
    pub fn spawn_job_qos(
        self: &Arc<Self>,
        qos: QoS,
        job: impl FnOnce(&Arc<EdgeFaaS>) + Send + 'static,
    ) {
        let deadline_ns = qos
            .deadline_s
            .map(|d| ((self.clock.now() + d.max(0.0)) * 1e9) as u64)
            .unwrap_or(u64::MAX);
        self.enqueue(vec![Task::Job {
            class: qos.priority,
            deadline_ns,
            job: Box::new(job),
        }]);
        let overflow = {
            let mut c = self.engine.coord.state.lock().unwrap();
            if c.workers.saturating_sub(c.busy) == 0 {
                c.workers += 1;
                true
            } else {
                false
            }
        };
        if overflow {
            self.spawn_worker();
        }
    }

    /// Subscribe to engine completion events. Callbacks run on worker
    /// threads after the engine's locks are released, so they may call back
    /// into the coordinator (e.g. `reschedule_function` on load changes).
    pub fn on_engine_event(&self, cb: impl Fn(&EdgeFaaS, &EngineEvent) + Send + Sync + 'static) {
        let mut cbs = self.engine.callbacks.write().unwrap();
        let mut v: Vec<EventCallback> = cbs.iter().cloned().collect();
        v.push(Arc::new(cb));
        *cbs = Arc::from(v);
    }

    /// Tune the engine: worker-thread cap and per-resource admission slots
    /// (both clamped to >= 1). Takes effect for subsequent scheduling
    /// decisions.
    pub fn set_engine_limits(self: &Arc<Self>, max_workers: usize, per_resource_slots: usize) {
        self.engine.max_workers.store(max_workers.max(1), Ordering::Relaxed);
        self.engine.per_resource_slots.store(per_resource_slots.max(1), Ordering::Relaxed);
        // A raised slot limit can turn admission-blocked work dispatchable
        // without any slot release: re-flag affected shards.
        self.refresh_dispatch();
    }

    /// Set the active shard count for the dispatch queues and the run
    /// table (clamped to `1..=`[`ENGINE_SHARDS`]). **Call on an idle
    /// engine only** (no queued work, no pending runs): shard routing of
    /// in-flight state is not rehashed. `1` reproduces the old
    /// single-lock engine (the contention bench's baseline); the default
    /// is [`ENGINE_SHARDS`].
    pub fn set_engine_shards(&self, shards: usize) {
        let eng = &self.engine;
        let busy = eng.pending_runs.load(Ordering::SeqCst) != 0
            || eng.queued_instances.load(Ordering::SeqCst) != 0
            || eng.queued_jobs.load(Ordering::SeqCst) != 0;
        debug_assert!(
            !busy,
            "set_engine_shards called on a non-idle engine: in-flight state is not rehashed"
        );
        if busy {
            // Release builds: refuse silently corrupting shard routing of
            // live runs; keep the current layout and say why.
            log::warn!(
                "set_engine_shards({shards}) ignored: engine not idle \
                 (pending runs or queued work present)"
            );
            return;
        }
        eng.active_shards.store(shards.clamp(1, ENGINE_SHARDS), Ordering::SeqCst);
    }

    /// The active shard count.
    pub fn engine_shards(&self) -> usize {
        self.engine.active()
    }

    /// Toggle per-resource invocation batching (see the module docs).
    /// Enabled by default with [`DEFAULT_MAX_BATCH`]; disabling dispatches
    /// every instance individually. Batching on or off, runs produce
    /// identical firing orders and outputs — only the dispatch overhead
    /// changes.
    pub fn set_batching(&self, enabled: bool) {
        self.set_max_batch(if enabled { DEFAULT_MAX_BATCH } else { 1 });
    }

    /// Cap the per-resource invocation batch size (clamped to >= 1; 1
    /// disables batching).
    pub fn set_max_batch(&self, max_batch: usize) {
        self.engine.max_batch.store(max_batch.max(1), Ordering::Relaxed);
    }

    /// Whether per-resource invocation batching is currently enabled.
    pub fn batching_enabled(&self) -> bool {
        self.engine.max_batch.load(Ordering::Relaxed) > 1
    }

    /// Adaptive dispatch window, seconds (0 disables; the default). While
    /// set, a worker that acquired an admission slot with a non-full batch
    /// holds it for up to the window — parked on its shard's condvar, so
    /// same-shard enqueues fill the batch early — before dispatching (see
    /// the module docs). Trades up to `window_s` of added latency for
    /// fewer backend round trips under light load.
    pub fn set_batch_window(&self, window_s: f64) {
        let ns = if window_s > 0.0 { (window_s * 1e9) as u64 } else { 0 };
        self.engine.batch_window_ns.store(ns, Ordering::Relaxed);
    }

    /// The configured adaptive dispatch window, seconds (0 = off).
    pub fn batch_window(&self) -> f64 {
        self.engine.batch_window_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Tune the backpressure bounds (both clamped to >= 1): total pending
    /// (not yet finished) runs, and queued instances per resource. Beyond
    /// either bound, submissions are refused with
    /// [`EngineError::Saturated`] — after `Batch`-class shedding for
    /// higher-class submissions (see [`Self::submit_workflow_qos`]).
    pub fn set_backpressure(&self, max_pending_runs: usize, max_queued_per_resource: usize) {
        self.engine.max_pending_runs.store(max_pending_runs.max(1), Ordering::Relaxed);
        self.engine
            .max_queued_per_resource
            .store(max_queued_per_resource.max(1), Ordering::Relaxed);
    }

    /// Snapshot of engine-wide counters (shards, pending runs, queue
    /// depth, worker pool, dispatch statistics).
    pub fn engine_stats(&self) -> EngineStats {
        let eng = &self.engine;
        let (workers, busy) = {
            let c = eng.coord.state.lock().unwrap();
            (c.workers, c.busy)
        };
        EngineStats {
            shards: eng.active(),
            pending_runs: eng.pending_runs.load(Ordering::SeqCst),
            queued_instances: eng.queued_instances.load(Ordering::SeqCst),
            workers,
            busy_workers: busy,
            batch_dispatches: eng.batch_dispatches.load(Ordering::Relaxed),
            instances_dispatched: eng.instances_dispatched.load(Ordering::Relaxed),
        }
    }

    /// Queued instances (ready + admission-deferred; jobs excluded) per
    /// active dispatch shard — the overload signal `GET /engine/stats`
    /// serves and federated work stealing polls for. Index = shard id.
    pub fn shard_queue_depths(&self) -> Vec<usize> {
        let eng = &self.engine;
        (0..eng.active())
            .map(|sid| {
                let st = eng.dispatch[sid].state.lock().unwrap();
                let ready =
                    st.ready.values().filter(|t| matches!(t, Task::Instance(_))).count();
                ready + st.deferred.len()
            })
            .collect()
    }

    /// Federation loan counters:
    /// `(lent, completed, requeued, reclaimed, outstanding)`.
    pub fn federation_loans(&self) -> (u64, u64, u64, u64, usize) {
        let eng = &self.engine;
        (
            eng.instances_lent.load(Ordering::Relaxed),
            eng.lent_completed.load(Ordering::Relaxed),
            eng.lent_requeued.load(Ordering::Relaxed),
            eng.lent_reclaimed.load(Ordering::Relaxed),
            eng.lent.lock().unwrap().len(),
        )
    }

    /// Export up to `max` queued instances from the deepest dispatch shard
    /// to a federated thief (`POST /federation/steal`, victim side). Tasks
    /// are popped from the *back* of the QoS order (lowest-urgency first,
    /// admission-deferred work first) — the shard's most imminent work
    /// keeps its local dispatch slot, classic steal semantics. Each
    /// exported task is recorded as a loan with deadline `now +
    /// reclaim_s`; the thief acknowledges through
    /// [`Self::complete_remote_instance`], and [`Self::reclaim_lent`]
    /// re-enqueues expired loans locally (same attempt id, so a reclaim
    /// racing a slow thief stays at-most-once at the backend). Only
    /// *queued* work is exported — run bookkeeping (`open_tasks`,
    /// admission slots) is untouched until the completion report.
    pub(super) fn export_stealable(
        self: &Arc<Self>,
        max: usize,
        reclaim_s: f64,
    ) -> Vec<StolenInstance> {
        let eng = &self.engine;
        if max == 0 {
            return Vec::new();
        }
        let depths = self.shard_queue_depths();
        let Some((sid, _)) =
            depths.iter().enumerate().filter(|(_, d)| **d > 0).max_by_key(|(_, d)| **d)
        else {
            return Vec::new();
        };
        let now = self.clock.now();
        let taken: Vec<InstanceTask> = {
            let mut st = eng.dispatch[sid].state.lock().unwrap();
            let mut out = Vec::new();
            let deferred_keys: Vec<QKey> =
                st.deferred.keys().rev().take(max).copied().collect();
            for k in deferred_keys {
                if let Some(t) = st.deferred.remove(&k) {
                    out.push(t);
                }
            }
            if out.len() < max {
                let ready_keys: Vec<QKey> = st
                    .ready
                    .iter()
                    .rev()
                    .filter(|(_, t)| matches!(t, Task::Instance(_)))
                    .take(max - out.len())
                    .map(|(k, _)| *k)
                    .collect();
                for k in ready_keys {
                    if let Some(Task::Instance(t)) = st.ready.remove(&k) {
                        out.push(t);
                    }
                }
            }
            if !out.is_empty() {
                eng.queued_instances.fetch_sub(out.len(), Ordering::SeqCst);
                let batch = out.iter().filter(|t| t.class == Priority::Batch).count();
                if batch > 0 {
                    eng.queued_batch_class.fetch_sub(batch, Ordering::SeqCst);
                }
            }
            out
        };
        if taken.is_empty() {
            return Vec::new();
        }
        let mut exported = Vec::with_capacity(taken.len());
        {
            let mut lent = eng.lent.lock().unwrap();
            for t in taken {
                exported.push(StolenInstance {
                    run: t.run,
                    app: t.app.clone(),
                    function: t.function.clone(),
                    instance: t.instance,
                    resource: t.resource,
                    class: t.class,
                    remaining_s: (t.deadline_ns != u64::MAX)
                        .then(|| (t.deadline_ns as f64 / 1e9 - now).max(0.0)),
                    envelope: t.envelope.clone(),
                    attempt: t.attempt,
                    retried: t.retried,
                });
                eng.instances_lent.fetch_add(1, Ordering::Relaxed);
                lent.insert(
                    (t.run, t.function.clone(), t.instance),
                    LentInstance { task: t, reclaim_at: now + reclaim_s.max(0.0) },
                );
            }
        }
        // Queued work vanished without a dispatch: parked workers must
        // re-evaluate (the shard may now be empty).
        eng.coord.cv.notify_all();
        exported
    }

    /// Settle a loan from its thief's completion report
    /// (`POST /federation/complete`, victim side). `requeue = true` hands
    /// the instance back unexecuted (the thief found no schedulable
    /// target) — it re-enters the local queue with its attempt id intact;
    /// otherwise the outcome flows through the normal completion
    /// bookkeeping exactly like a local dispatch. Returns `false` when no
    /// such loan is outstanding (already reclaimed or double-reported —
    /// the report is dropped, preserving at-most-once bookkeeping).
    pub(super) fn complete_remote_instance(
        self: &Arc<Self>,
        run: RunId,
        function: &str,
        instance: usize,
        outcome: anyhow::Result<InstanceResult>,
        requeue: bool,
    ) -> bool {
        let eng = &self.engine;
        let loan = eng.lent.lock().unwrap().remove(&(run, function.to_string(), instance));
        let Some(loan) = loan else { return false };
        if requeue {
            eng.lent_requeued.fetch_add(1, Ordering::Relaxed);
            self.enqueue(vec![Task::Instance(loan.task)]);
        } else {
            eng.lent_completed.fetch_add(1, Ordering::Relaxed);
            self.complete_batch(std::slice::from_ref(&loan.task), vec![Some(outcome)]);
        }
        true
    }

    /// Re-enqueue every loan past its reclaim deadline (the thief died or
    /// partitioned mid-steal). Attempt ids are preserved, so if the thief
    /// did execute before vanishing, the backend's attempt cache replays
    /// the recorded outcome instead of re-executing. Returns the number
    /// reclaimed.
    pub(super) fn reclaim_lent(self: &Arc<Self>) -> usize {
        let now = self.clock.now();
        let expired: Vec<InstanceTask> = {
            let mut lent = self.engine.lent.lock().unwrap();
            let keys: Vec<(RunId, String, usize)> = lent
                .iter()
                .filter(|(_, l)| now >= l.reclaim_at)
                .map(|(k, _)| k.clone())
                .collect();
            keys.into_iter().filter_map(|k| lent.remove(&k)).map(|l| l.task).collect()
        };
        if expired.is_empty() {
            return 0;
        }
        let n = expired.len();
        self.engine.lent_reclaimed.fetch_add(n as u64, Ordering::Relaxed);
        self.enqueue(expired.into_iter().map(Task::Instance).collect());
        n
    }

    // ------------------------------------------------------------ internal --

    /// Key tasks, route them to their shards (an instance to its
    /// resource's shard, a job spread by sequence), flag every shard that
    /// became dispatchable, and spawn workers for uncovered flags. Keys
    /// are assigned from the global sequence in task order, so the FIFO
    /// tie-break is identical at every shard count.
    fn enqueue(self: &Arc<Self>, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let eng = &self.engine;
        let active = eng.active();
        let limit = eng.per_resource_slots.load(Ordering::Relaxed).max(1);
        let mut by_shard: BTreeMap<usize, Vec<(QKey, Task)>> = BTreeMap::new();
        for t in tasks {
            let seq = eng.next_seq.fetch_add(1, Ordering::SeqCst);
            let key = QKey { class: t.class().rank(), deadline_ns: t.deadline_ns(), seq };
            let sid = match &t {
                Task::Instance(ti) => eng.dispatch_shard_of(ti.resource),
                Task::Job { .. } => (seq % active as u64) as usize,
            };
            by_shard.entry(sid).or_default().push((key, t));
        }
        let mut spawns = 0usize;
        for (sid, group) in by_shard {
            let shard = &eng.dispatch[sid];
            let mut st = shard.state.lock().unwrap();
            for (key, t) in group {
                match &t {
                    Task::Instance(_) => {
                        eng.queued_instances.fetch_add(1, Ordering::SeqCst);
                    }
                    Task::Job { .. } => {
                        eng.queued_jobs.fetch_add(1, Ordering::SeqCst);
                    }
                }
                if t.class() == Priority::Batch {
                    eng.queued_batch_class.fetch_add(1, Ordering::SeqCst);
                }
                st.ready.insert(key, t);
            }
            if let Some(rank) = poppable_rank(&st, limit) {
                if eng.flag_shard_locked(&mut st, sid, rank) {
                    spawns += 1;
                }
            }
            // Wake an adaptive-window holder parked on this shard.
            shard.cv.notify_all();
        }
        for _ in 0..spawns {
            self.spawn_worker();
        }
    }

    /// Spawn one worker thread; the coord `workers` count was already
    /// incremented by the caller's accounting, so a failed spawn rolls it
    /// back.
    fn spawn_worker(self: &Arc<Self>) {
        let faas = Arc::clone(self);
        let spawned = std::thread::Builder::new()
            .name("engine-worker".into())
            .spawn(move || engine_worker(faas));
        if spawned.is_err() {
            self.engine.coord.state.lock().unwrap().workers -= 1;
        }
    }

    /// Re-flag every active shard that has dispatchable work (after a
    /// limits change) and wake the pool.
    fn refresh_dispatch(self: &Arc<Self>) {
        let eng = &self.engine;
        let limit = eng.per_resource_slots.load(Ordering::Relaxed).max(1);
        let mut spawns = 0usize;
        for sid in 0..eng.active() {
            let mut st = eng.dispatch[sid].state.lock().unwrap();
            if let Some(rank) = poppable_rank(&st, limit) {
                if eng.flag_shard_locked(&mut st, sid, rank) {
                    spawns += 1;
                }
            }
        }
        for _ in 0..spawns {
            self.spawn_worker();
        }
        eng.coord.cv.notify_all();
    }

    /// Fire one DAG node: route its inputs, record bookkeeping, and collect
    /// one task per placement instance into `batch`.
    ///
    /// Envelopes are assembled here, once per instance, into shared
    /// [`Bytes`]: the node-common `{"app":...,"function":...` head is
    /// serialized exactly once and shared across placements, and workers
    /// never rebuild or re-serialize a JSON tree on the dispatch path. Key
    /// order (`app`, `function`, `inputs`, `resource`) matches the sorted
    /// order [`Json`] serialization used, so the wire format is unchanged.
    fn fire_node(
        &self,
        run: RunId,
        entry: &mut RunEntry,
        fname: &str,
        batch: &mut Vec<Task>,
    ) -> anyhow::Result<()> {
        if !entry.fired.insert(fname.to_string()) {
            return Ok(());
        }
        let app = entry.app_name.clone();
        let placements = self.candidates_of(&app, fname)?;
        if placements.is_empty() {
            anyhow::bail!("function `{app}.{fname}` has no placements");
        }
        let per_instance =
            self.route_inputs(&app, fname, &placements, &entry.entry_inputs, &entry.result)?;
        entry.result.firing_order.push(fname.to_string());
        entry.pending.insert(fname.to_string(), placements.len());
        entry.partial.insert(fname.to_string(), vec![None; placements.len()]);
        entry.open_tasks += placements.len();
        let class = entry.qos.priority;
        let deadline_ns =
            entry.deadline_abs.map(|d| (d.max(0.0) * 1e9) as u64).unwrap_or(u64::MAX);
        // Serialize the node-common envelope head once (JSON-escaped).
        let mut head = String::with_capacity(32 + app.len() + fname.len());
        head.push_str("{\"app\":");
        head.push_str(&Json::Str(app.clone()).to_string());
        head.push_str(",\"function\":");
        head.push_str(&Json::Str(fname.to_string()).to_string());
        for (i, (rid, inputs)) in placements.into_iter().zip(per_instance).enumerate() {
            let inputs_json = Json::Arr(inputs.into_iter().map(Json::Str).collect()).to_string();
            let mut env = String::with_capacity(head.len() + inputs_json.len() + 24);
            env.push_str(&head);
            env.push_str(",\"inputs\":");
            env.push_str(&inputs_json);
            env.push_str(",\"resource\":");
            env.push_str(&(rid as u64).to_string());
            env.push('}');
            batch.push(Task::Instance(InstanceTask {
                run,
                app: app.clone(),
                function: fname.to_string(),
                instance: i,
                resource: rid,
                class,
                deadline_ns,
                envelope: Bytes::from(env),
                attempt: self.engine.next_attempt.fetch_add(1, Ordering::Relaxed),
                retried: false,
            }));
        }
        Ok(())
    }

    /// Execute a drained same-resource batch and fan the results back out
    /// to their runs. A batch of one takes the exact single-instance path;
    /// larger batches go through the backend's `Batch` verb
    /// ([`super::handle::ResourceHandle::invoke_batch`]) — one gateway
    /// round trip, per-entry failure containment, results in task order.
    fn run_batch(self: &Arc<Self>, rid: ResourceId, tasks: Vec<InstanceTask>) {
        let eng = &self.engine;
        // Fast-drain instances of runs that already failed or finished
        // (one lock per affected run shard for the whole batch). Like the
        // unbatched path — where siblings already executing on other
        // workers cannot be recalled either — this check is best-effort: a
        // run failing mid-batch wastes at most the remainder of this one
        // batch.
        //
        // Deadline enforcement lives here too: an instance dispatched after
        // its run's deadline has passed is skipped instead of occupying the
        // backend, the run transitions to `DeadlineExceeded` (once), and
        // `EngineEvent::DeadlineMissed` fires for reschedule policies.
        let now = self.clock.now();
        let mut deadline_events: Vec<(usize, EngineEvent)> = Vec::new();
        let mut skip = vec![false; tasks.len()];
        for (sid, idxs) in Self::by_run_shard(eng, &tasks) {
            let mut rs = eng.runs[sid].state.lock().unwrap();
            for i in idxs {
                let t = &tasks[i];
                let Some(e) = rs.map.get_mut(&t.run) else {
                    skip[i] = true;
                    continue;
                };
                if e.failed.is_some() || e.done {
                    skip[i] = true;
                    continue;
                }
                if let Some(d) = e.deadline_abs {
                    if now >= d {
                        e.deadline_missed = true;
                        e.failed = Some(format!(
                            "deadline exceeded: dispatched {:.3}s past the {:.3}s deadline",
                            now - d,
                            e.qos.deadline_s.unwrap_or(0.0)
                        ));
                        deadline_events.push((
                            i,
                            EngineEvent::DeadlineMissed {
                                run: t.run,
                                app: e.app_name.clone(),
                                deadline_s: e.qos.deadline_s.unwrap_or(0.0),
                                late_by: now - d,
                            },
                        ));
                        skip[i] = true;
                    }
                }
            }
        }
        // Emit in task order regardless of shard visit order.
        deadline_events.sort_by_key(|(i, _)| *i);
        let deadline_events: Vec<EngineEvent> =
            deadline_events.into_iter().map(|(_, ev)| ev).collect();
        self.emit_events(&deadline_events);
        let mut outcomes: Vec<Option<anyhow::Result<InstanceResult>>> =
            skip.iter().map(|_| None).collect();
        let live: Vec<usize> = (0..tasks.len()).filter(|&i| !skip[i]).collect();
        // Statistics count *backend* dispatches only: a batch whose tasks
        // were all skipped (run failed/shed, deadline missed) never reaches
        // a backend and must not inflate the counters the contention bench
        // and the window test read.
        if !live.is_empty() {
            eng.batch_dispatches.fetch_add(1, Ordering::Relaxed);
            eng.instances_dispatched.fetch_add(live.len() as u64, Ordering::Relaxed);
        }
        match live.len() {
            0 => {}
            1 => {
                let i = live[0];
                outcomes[i] = Some(run_instance(self, &tasks[i]));
            }
            _ => match self.resource(rid) {
                Err(e) => {
                    let msg = e.to_string();
                    for &i in &live {
                        outcomes[i] = Some(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
                Ok(reg) => {
                    // Refcount bumps only: the envelopes were built at fire
                    // time and are shared with the backend call.
                    let calls: Vec<BatchCall> = live
                        .iter()
                        .map(|&i| {
                            let t = &tasks[i];
                            BatchCall {
                                name: EdgeFaaS::qualified(&t.app, &t.function),
                                payload: t.envelope.clone(),
                                attempt: t.attempt,
                                budget: remaining_budget(t.deadline_ns, now),
                            }
                        })
                        .collect();
                    let invoked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        reg.handle.invoke_batch(&calls)
                    }));
                    match invoked {
                        Ok(results) => {
                            // Enforce the one-result-per-call contract: a
                            // misbehaving handle returning too few results
                            // must fail the unmatched tasks loudly, not
                            // strand them as "skipped" (which would wedge
                            // the run's pending count forever).
                            let mut results = results.into_iter();
                            for &i in &live {
                                outcomes[i] = Some(match results.next() {
                                    Some(result) => result.and_then(|(out, latency)| {
                                        Ok(InstanceResult {
                                            resource: rid,
                                            outputs: parse_outputs(&out)?,
                                            latency,
                                        })
                                    }),
                                    None => Err(anyhow::anyhow!(
                                        "backend returned too few batch results"
                                    )),
                                });
                            }
                        }
                        Err(payload) => {
                            // Only a handle without per-entry containment
                            // can unwind to here; fail the whole batch.
                            let what = crate::util::panic_message(&*payload);
                            for &i in &live {
                                outcomes[i] = Some(Err(anyhow::anyhow!(
                                    "function handler panicked: {what}"
                                )));
                            }
                        }
                    }
                }
            },
        }
        // At-most-once in-flight retry: failed entries whose resource
        // looks dead move to a surviving candidate before the failure
        // reaches the run bookkeeping. Runs outside every engine lock.
        let retries = self.plan_liveness_retries(rid, &tasks, &mut outcomes);
        self.complete_batch(&tasks, outcomes);
        if !retries.is_empty() {
            self.enqueue(retries);
        }
    }

    /// Decide which failed entries of a just-executed batch to retry on a
    /// surviving resource, and which to convert into typed
    /// `ResourceDead` failures.
    ///
    /// The gate is an *infrastructure* check, not the per-entry error: the
    /// resource's lease is unschedulable (Dead/Recovering), it was
    /// unregistered, or a direct probe fails (covers a resource killed
    /// before the detector's first sweep saw it). An application error
    /// from a healthy resource is never retried.
    ///
    /// For each retried entry: the run's `open_tasks` is raised *before*
    /// the entry's own decrement in `complete_batch` (so the run cannot
    /// transiently drain to zero and complete early), the outcome becomes
    /// a skip, and a re-anchored copy of the task — same attempt id, so a
    /// backend that executed the first attempt deduplicates it; `retried`
    /// set, so it is never retried again — is returned for enqueueing
    /// after `complete_batch`. Entries with no survivor (or already
    /// retried once) fail typed: the run's `dead_resource` is recorded and
    /// the error message names the dead resource.
    fn plan_liveness_retries(
        self: &Arc<Self>,
        rid: ResourceId,
        tasks: &[InstanceTask],
        outcomes: &mut [Option<anyhow::Result<InstanceResult>>],
    ) -> Vec<Task> {
        let eng = &self.engine;
        let any_failed =
            (0..tasks.len()).any(|i| matches!(&outcomes[i], Some(Err(_))));
        if !any_failed {
            return Vec::new();
        }
        // Data-path liveness: a connectivity-class failure (connect
        // refused/timed out, deadline, reset, truncation — never an
        // application error or HTTP status) on the invoke path is itself
        // lease evidence. Report it as a missed lease *before* reading the
        // snapshot, so a partitioned resource turns Suspect from live
        // traffic — between detector sweeps — and the infra gate below sees
        // the degraded lease immediately.
        let conn_failed = (0..tasks.len()).any(|i| {
            matches!(&outcomes[i], Some(Err(e)) if super::handle::is_connectivity_error(e))
        });
        if conn_failed {
            self.report_data_path_miss(rid);
        }
        let snap = self.monitor_snapshot();
        let lease_bad =
            snap.lease_of(rid).map(|l| !l.state.schedulable()).unwrap_or(false);
        let infra_dead = lease_bad
            || match self.resource(rid) {
                Err(_) => true,
                Ok(reg) => reg.handle.usage().is_err(),
            };
        if !infra_dead {
            return Vec::new();
        }
        let mut retries = Vec::new();
        for i in 0..tasks.len() {
            if !matches!(&outcomes[i], Some(Err(_))) {
                continue;
            }
            let t = &tasks[i];
            let candidates = self.candidates_of(&t.app, &t.function).unwrap_or_default();
            // Prefer a different, schedulable resource; fall back to the
            // same node only when it is the sole candidate — the backend's
            // attempt-id dedup makes that retry safe even if the first
            // attempt executed.
            let survivor = candidates
                .iter()
                .copied()
                .find(|&r| {
                    r != rid
                        && self.resource(r).is_ok()
                        && snap.lease_of(r).map(|l| l.state.schedulable()).unwrap_or(true)
                })
                .or_else(|| {
                    candidates.iter().copied().find(|&r| r == rid && self.resource(r).is_ok())
                });
            let target = match (t.retried, survivor) {
                (false, Some(target)) => target,
                _ => {
                    // Out of retries or out of survivors: make the failure
                    // typed. `complete_batch` records the message; the
                    // `dead_resource` mark turns the wait into
                    // [`WaitError::ResourceDead`].
                    let orig = match &outcomes[i] {
                        Some(Err(e)) => e.to_string(),
                        _ => unreachable!("filtered above"),
                    };
                    outcomes[i] = Some(Err(anyhow::anyhow!(
                        "resource {rid} is dead and no surviving candidate remains \
                         (ResourceDead): {orig}"
                    )));
                    let rsid = eng.run_shard_of(t.run);
                    let mut rs = eng.runs[rsid].state.lock().unwrap();
                    if let Some(entry) = rs.map.get_mut(&t.run) {
                        entry.dead_resource.get_or_insert(rid);
                    }
                    continue;
                }
            };
            // Raise open_tasks before complete_batch's decrement; skip the
            // retry when the run is already failed/done (nothing to save).
            let alive = {
                let rsid = eng.run_shard_of(t.run);
                let mut rs = eng.runs[rsid].state.lock().unwrap();
                match rs.map.get_mut(&t.run) {
                    Some(entry) if entry.failed.is_none() && !entry.done => {
                        entry.open_tasks += 1;
                        true
                    }
                    _ => false,
                }
            };
            if !alive {
                continue;
            }
            log::warn!(
                "retrying instance {} of `{}.{}` (run {}, attempt {}) on resource {target} \
                 after resource {rid} died",
                t.instance, t.app, t.function, t.run, t.attempt
            );
            outcomes[i] = None; // skip: the retry owns this entry's result now
            retries.push(Task::Instance(InstanceTask {
                run: t.run,
                app: t.app.clone(),
                function: t.function.clone(),
                instance: t.instance,
                resource: target,
                class: t.class,
                deadline_ns: t.deadline_ns,
                envelope: patch_envelope_resource(&t.envelope, target),
                attempt: t.attempt,
                retried: true,
            }));
        }
        retries
    }

    /// Group a batch's task indices by run shard (ascending shard order,
    /// task order within a shard). Tasks of one run always share a shard,
    /// so per-run invariants hold within one lock session.
    fn by_run_shard(eng: &EngineCore, tasks: &[InstanceTask]) -> BTreeMap<usize, Vec<usize>> {
        let mut by_shard: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, t) in tasks.iter().enumerate() {
            by_shard.entry(eng.run_shard_of(t.run)).or_default().push(i);
        }
        by_shard
    }

    /// Process a batch of finished (or skipped) instances, sequentially in
    /// task order within each affected run shard — exactly the bookkeeping
    /// N single completions would do, but with each run shard's lock taken
    /// twice per batch instead of twice per task.
    ///
    /// Two lock phases with the node-completion events emitted *between*
    /// them: subscribers observe `NodeCompleted` before the node's
    /// dependents are scheduled, so a callback (e.g. one invoking
    /// `reschedule_function` against fresh monitoring data) can still
    /// influence where the next stage lands.
    fn complete_batch(
        self: &Arc<Self>,
        tasks: &[InstanceTask],
        mut outcomes: Vec<Option<anyhow::Result<InstanceResult>>>,
    ) {
        let eng = &self.engine;
        let by_shard = Self::by_run_shard(eng, tasks);
        // Phase 1: record every instance; detect node completions.
        let mut node_events: Vec<Option<EngineEvent>> = (0..tasks.len()).map(|_| None).collect();
        let mut node_done = vec![false; tasks.len()];
        for (sid, idxs) in &by_shard {
            let mut rs = eng.runs[*sid].state.lock().unwrap();
            for &idx in idxs {
                let task = &tasks[idx];
                let outcome = outcomes[idx].take();
                let Some(entry) = rs.map.get_mut(&task.run) else { continue };
                entry.open_tasks = entry.open_tasks.saturating_sub(1);
                match outcome {
                    None => {} // skipped: the run had already failed
                    Some(Ok(r)) => {
                        if entry.failed.is_none() {
                            if let Some(slots) = entry.partial.get_mut(&task.function) {
                                slots[task.instance] = Some(r);
                            }
                            node_done[idx] = match entry.pending.get_mut(&task.function) {
                                Some(p) => {
                                    *p -= 1;
                                    *p == 0
                                }
                                None => false,
                            };
                            if node_done[idx] {
                                entry.pending.remove(&task.function);
                                let slots =
                                    entry.partial.remove(&task.function).unwrap_or_default();
                                let instances: Vec<InstanceResult> =
                                    slots.into_iter().flatten().collect();
                                let latency =
                                    instances.iter().map(|i| i.latency).fold(0.0, f64::max);
                                let instance_latencies: Vec<(ResourceId, f64)> =
                                    instances.iter().map(|i| (i.resource, i.latency)).collect();
                                node_events[idx] = Some(EngineEvent::NodeCompleted {
                                    run: task.run,
                                    app: entry.app_name.clone(),
                                    function: task.function.clone(),
                                    instances: instances.len(),
                                    latency,
                                    instance_latencies,
                                });
                                entry.result.functions.insert(task.function.clone(), instances);
                            }
                        }
                    }
                    Some(Err(e)) => {
                        let msg = format!(
                            "workflow `{}` function `{}` on resource {}: {e}",
                            entry.app_name, task.function, task.resource
                        );
                        log::warn!("{msg}");
                        entry.failed.get_or_insert(msg);
                        entry.pending.remove(&task.function);
                        entry.partial.remove(&task.function);
                    }
                }
            }
        }
        // Emit in task order regardless of shard visit order.
        let node_events: Vec<EngineEvent> = node_events.into_iter().flatten().collect();
        self.emit_events(&node_events);

        // Phase 2: fire newly-ready dependents (sorted by topological index
        // for deterministic firing orders) in task order — for EVERY
        // completed node of a run before that run's completion check. Two
        // batch entries can belong to one run, and `check_done` treats
        // `open_tasks == 0` as run-complete: checking an earlier entry's
        // run before a later entry fired its dependents would retire the
        // run with downstream nodes unfired. Tasks of one run share a run
        // shard, so the invariant holds within each shard's lock session.
        // New tasks are collected per entry index and enqueued once, in
        // task order, so the FIFO sequence matches unsharded execution.
        let mut run_events = Vec::new();
        let mut to_enqueue: Vec<Vec<Task>> = (0..tasks.len()).map(|_| Vec::new()).collect();
        let mut completed_shards: Vec<usize> = Vec::new();
        for (sid, idxs) in &by_shard {
            let mut rs = eng.runs[*sid].state.lock().unwrap();
            for &idx in idxs {
                if !node_done[idx] {
                    continue;
                }
                let task = &tasks[idx];
                let Some(entry) = rs.map.get_mut(&task.run) else { continue };
                if entry.failed.is_some() {
                    continue;
                }
                let application = Arc::clone(&entry.app);
                let mut ready = entry.state.complete(&application.dag, &task.function);
                ready.sort_by_key(|n| {
                    application.dag.topo_order.iter().position(|x| x == n).unwrap_or(usize::MAX)
                });
                for f in &ready {
                    if let Err(e) = self.fire_node(task.run, entry, f, &mut to_enqueue[idx]) {
                        entry.failed.get_or_insert(e.to_string());
                        break;
                    }
                }
            }
            // Now detect run completions (idempotent per run via the `done`
            // flag, so duplicate runs in one batch check harmlessly twice).
            for &idx in idxs {
                let task = &tasks[idx];
                let completed = match rs.map.get_mut(&task.run) {
                    None => false,
                    Some(entry) => self.check_done(task.run, entry, &mut run_events),
                };
                if completed {
                    Self::retire_finished(eng, &mut rs, task.run);
                    completed_shards.push(*sid);
                }
            }
        }
        // One enqueue (shard locks + wakeups) for the whole batch, in task
        // order. The entries are already visible in their run shards.
        let to_enqueue: Vec<Task> = to_enqueue.into_iter().flatten().collect();
        if to_enqueue.is_empty() {
            // Nothing new to dispatch: let any parked workers re-evaluate —
            // if the engine just went idle they exit instead of lingering.
            eng.coord.cv.notify_all();
        } else {
            self.enqueue(to_enqueue);
        }
        for sid in completed_shards {
            eng.runs[sid].done_cv.notify_all();
        }
        self.emit_events(&run_events);
    }

    /// Drain every *queued* instance bound for a dead resource out of its
    /// dispatch shard: instances with a surviving schedulable candidate
    /// are re-anchored onto it (attempt id preserved, retry budget
    /// untouched — a queued instance never executed), the rest fail their
    /// runs with a typed `ResourceDead` cause so no `wait_workflow` caller
    /// hangs. In-flight instances are not touched here; they surface
    /// through the batch path's at-most-once retry
    /// ([`Self::plan_liveness_retries`]). Jobs and other resources' work
    /// in the same shard are left in place. Returns `(moved, failed)`.
    pub(super) fn drain_dead_resource(self: &Arc<Self>, rid: ResourceId) -> (usize, usize) {
        let eng = &self.engine;
        let sid = eng.dispatch_shard_of(rid);
        // Phase A (dispatch shard lock): pull the dead resource's queued
        // instances and settle the global queue counters.
        let stranded: Vec<InstanceTask> = {
            let mut st = eng.dispatch[sid].state.lock().unwrap();
            let mut out = Vec::new();
            let ready_keys: Vec<QKey> = st
                .ready
                .iter()
                .filter(|(_, t)| matches!(t, Task::Instance(ti) if ti.resource == rid))
                .map(|(k, _)| *k)
                .collect();
            for k in ready_keys {
                if let Some(Task::Instance(t)) = st.ready.remove(&k) {
                    out.push(t);
                }
            }
            let deferred_keys: Vec<QKey> = st
                .deferred
                .iter()
                .filter(|(_, t)| t.resource == rid)
                .map(|(k, _)| *k)
                .collect();
            for k in deferred_keys {
                if let Some(t) = st.deferred.remove(&k) {
                    out.push(t);
                }
            }
            if !out.is_empty() {
                eng.queued_instances.fetch_sub(out.len(), Ordering::SeqCst);
                let batch = out.iter().filter(|t| t.class == Priority::Batch).count();
                if batch > 0 {
                    eng.queued_batch_class.fetch_sub(batch, Ordering::SeqCst);
                }
            }
            out
        };
        if stranded.is_empty() {
            return (0, 0);
        }
        // Phase B (run shard locks only — never nested under the dispatch
        // lock): re-anchor or fail each instance.
        let snap = self.monitor_snapshot();
        let mut moved: Vec<Task> = Vec::new();
        let mut failed = 0usize;
        let mut run_events = Vec::new();
        let mut completed_shards: Vec<usize> = Vec::new();
        for mut t in stranded {
            let survivor = self
                .candidates_of(&t.app, &t.function)
                .unwrap_or_default()
                .into_iter()
                .find(|&r| {
                    r != rid
                        && self.resource(r).is_ok()
                        && snap.lease_of(r).map(|l| l.state.schedulable()).unwrap_or(true)
                });
            match survivor {
                Some(target) => {
                    t.envelope = patch_envelope_resource(&t.envelope, target);
                    t.resource = target;
                    moved.push(Task::Instance(t));
                }
                None => {
                    failed += 1;
                    let rsid = eng.run_shard_of(t.run);
                    let mut rs = eng.runs[rsid].state.lock().unwrap();
                    let Some(entry) = rs.map.get_mut(&t.run) else { continue };
                    entry.open_tasks = entry.open_tasks.saturating_sub(1);
                    entry.dead_resource.get_or_insert(rid);
                    entry.failed.get_or_insert_with(|| {
                        format!(
                            "workflow `{}` function `{}`: resource {rid} died with no \
                             surviving candidate (ResourceDead)",
                            entry.app_name, t.function
                        )
                    });
                    entry.pending.remove(&t.function);
                    entry.partial.remove(&t.function);
                    if self.check_done(t.run, entry, &mut run_events) {
                        Self::retire_finished(eng, &mut rs, t.run);
                        completed_shards.push(rsid);
                    }
                }
            }
        }
        let moved_count = moved.len();
        if moved.is_empty() {
            // Queued work vanished without dispatching: parked workers must
            // re-evaluate (and exit if the engine just went idle).
            eng.coord.cv.notify_all();
        } else {
            self.enqueue(moved);
        }
        for rsid in completed_shards {
            eng.runs[rsid].done_cv.notify_all();
        }
        self.emit_events(&run_events);
        (moved_count, failed)
    }

    /// Live engine work bound for one resource: the runs with instances
    /// queued on it (sorted, deduplicated) plus the queued and in-flight
    /// counts — what `unregister`'s [`ResourceBusy`] refusal reports.
    pub(super) fn live_instances_on(&self, rid: ResourceId) -> (Vec<RunId>, usize, usize) {
        let eng = &self.engine;
        let st = eng.dispatch[eng.dispatch_shard_of(rid)].state.lock().unwrap();
        let mut runs: Vec<RunId> = Vec::new();
        let mut queued = 0usize;
        for t in st.ready.values() {
            if let Task::Instance(ti) = t {
                if ti.resource == rid {
                    queued += 1;
                    runs.push(ti.run);
                }
            }
        }
        for t in st.deferred.values() {
            if t.resource == rid {
                queued += 1;
                runs.push(t.run);
            }
        }
        let in_flight = st.in_use.get(&rid).copied().unwrap_or(0);
        drop(st);
        runs.sort_unstable();
        runs.dedup();
        (runs, queued, in_flight)
    }

    /// Mark a drained run done; returns true on the completing transition.
    fn check_done(&self, run: RunId, entry: &mut RunEntry, events: &mut Vec<EngineEvent>) -> bool {
        if !entry.done && entry.open_tasks == 0 {
            entry.done = true;
            entry.result.duration = self.clock.now() - entry.started;
            events.push(EngineEvent::RunCompleted {
                run,
                app: entry.app_name.clone(),
                ok: entry.failed.is_none(),
                duration: entry.result.duration,
            });
            return true;
        }
        false
    }

    /// Record a just-completed run in its shard's retention queue, evicting
    /// the oldest completed-but-unconsumed runs beyond this shard's share
    /// of [`MAX_FINISHED_RUNS`]. (Runs consumed by
    /// `wait_workflow`/`take_run` leave stale ids behind; those pop
    /// harmlessly here.) Called exactly once per completing transition
    /// (`check_done` returning true), so it also settles the global
    /// pending-run counter.
    fn retire_finished(eng: &EngineCore, rs: &mut RunShardState, run: RunId) {
        eng.pending_runs.fetch_sub(1, Ordering::SeqCst);
        // Split the global retention bound across the active shards so the
        // total stays MAX_FINISHED_RUNS at every shard count.
        let shard_cap = (MAX_FINISHED_RUNS / eng.active()).max(1);
        while rs.finished.len() >= shard_cap {
            let Some(old) = rs.finished.pop_front() else { break };
            if rs.map.get(&old).map(|e| e.done).unwrap_or(false) {
                rs.map.remove(&old);
            }
        }
        rs.finished.push_back(run);
    }

    pub(super) fn emit_events(&self, events: &[EngineEvent]) {
        if events.is_empty() {
            return;
        }
        // Clone the Arc under the read lock — never the callback list.
        let cbs: Arc<[EventCallback]> = Arc::clone(&self.engine.callbacks.read().unwrap());
        for ev in events {
            for cb in cbs.iter() {
                cb(self, ev);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::functions::FunctionPackage;
    use crate::simnet::{RealClock, VirtualClock};
    use crate::testbed::{paper_testbed, TestBed};
    use std::sync::atomic::AtomicUsize;

    /// A two-stage chain app: `gen` on the first two Pis -> `sum` on an
    /// edge, with counting handlers that thread a run tag through object
    /// URLs so concurrent runs are distinguishable.
    fn chain_bed(clock: Arc<dyn crate::simnet::Clock>) -> TestBed {
        let b = paper_testbed(clock);
        let faas = Arc::clone(&b.faas);
        let yaml = "\
application: chain
entrypoint: gen
dag:
  - name: gen
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: sum
    dependencies: gen
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";
        let mut data = HashMap::new();
        data.insert("gen".to_string(), vec![b.iot[0], b.iot[1]]);
        faas.configure_application(yaml, &data).unwrap();
        faas.create_bucket("chain", "work", Some(b.edges[0])).unwrap();
        {
            let faas = Arc::clone(&faas);
            b.executor.register("img/gen", move |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let rid = v.get("resource").unwrap().as_u64().unwrap();
                // Entry inputs carry the run tag (one URL-ish string).
                let tag = v
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .and_then(|a| a.first())
                    .and_then(Json::as_str)
                    .unwrap_or("r?")
                    .rsplit('/')
                    .next()
                    .unwrap_or("r?")
                    .to_string();
                let obj = format!("{tag}-gen-{rid}.bin");
                let url = faas.put_object("chain", "work", &obj, tag.as_bytes())?;
                let mut out = Json::obj();
                out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
                Ok(out.to_string().into_bytes())
            });
        }
        {
            let faas = Arc::clone(&faas);
            b.executor.register("img/sum", move |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let inputs = v.get("inputs").and_then(Json::as_arr).unwrap_or(&[]).to_vec();
                let mut tags: Vec<String> = Vec::new();
                for u in &inputs {
                    let data = faas.get_object_url(u.as_str().unwrap())?;
                    tags.push(String::from_utf8_lossy(&data).to_string());
                }
                tags.sort();
                tags.dedup();
                anyhow::ensure!(tags.len() == 1, "inputs from mixed runs: {tags:?}");
                let obj = format!("{}-sum-n{}.bin", tags[0], inputs.len());
                let url = faas.put_object("chain", "work", &obj, tags[0].as_bytes())?;
                let mut out = Json::obj();
                out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
                Ok(out.to_string().into_bytes())
            });
        }
        faas.deploy_function("chain", "gen", &FunctionPackage { code: "img/gen".into() })
            .unwrap();
        faas.deploy_function("chain", "sum", &FunctionPackage { code: "img/sum".into() })
            .unwrap();
        b
    }

    fn entry_for(run_tag: &str) -> HashMap<String, Vec<String>> {
        // Two pseudo-URL entry inputs; routing sends one to each gen
        // instance (parsing requires app/bucket/rid/object shape).
        let mut m = HashMap::new();
        m.insert(
            "gen".to_string(),
            vec![format!("chain/work/0/{run_tag}"), format!("chain/work/1/{run_tag}")],
        );
        m
    }

    #[test]
    fn submit_then_wait_runs_the_dag() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let run = b.faas.submit_workflow("chain", &entry_for("r0")).unwrap();
        let result = b.faas.wait_workflow(run, 10.0).unwrap();
        assert_eq!(result.firing_order, vec!["gen", "sum"]);
        assert_eq!(result.functions["gen"].len(), 2);
        assert_eq!(result.functions["sum"].len(), 1);
        assert!(result.functions["sum"][0].outputs[0].contains("r0-sum-n2"));
        // The record was consumed.
        assert!(b.faas.run_status(run).is_none());
        assert!(b.faas.wait_workflow(run, 0.1).is_err());
    }

    #[test]
    fn concurrent_runs_interleave_and_stay_isolated() {
        for clock in [
            Arc::new(RealClock::new()) as Arc<dyn crate::simnet::Clock>,
            Arc::new(VirtualClock::new()) as Arc<dyn crate::simnet::Clock>,
        ] {
            let b = chain_bed(clock);
            let runs: Vec<(String, RunId)> = (0..6)
                .map(|i| {
                    let tag = format!("r{i}");
                    let id = b.faas.submit_workflow("chain", &entry_for(&tag)).unwrap();
                    (tag, id)
                })
                .collect();
            for (tag, id) in runs {
                let result = b.faas.wait_workflow(id, 30.0).unwrap();
                let out = &result.functions["sum"][0].outputs[0];
                assert!(
                    out.contains(&format!("{tag}-sum-n2")),
                    "run {tag} got cross-contaminated: {out}"
                );
                assert_eq!(result.firing_order, vec!["gen", "sum"]);
            }
        }
    }

    #[test]
    fn batching_on_and_off_produce_identical_results() {
        for enabled in [false, true] {
            let b = chain_bed(Arc::new(RealClock::new()));
            b.faas.set_batching(enabled);
            assert_eq!(b.faas.batching_enabled(), enabled);
            // One admission slot per resource forces queuing, so the
            // batched pass actually forms multi-task batches.
            b.faas.set_engine_limits(8, 1);
            let runs: Vec<(String, RunId)> = (0..6)
                .map(|i| {
                    let tag = format!("r{i}");
                    let id = b.faas.submit_workflow("chain", &entry_for(&tag)).unwrap();
                    (tag, id)
                })
                .collect();
            for (tag, id) in runs {
                let result = b.faas.wait_workflow(id, 30.0).unwrap();
                assert_eq!(result.firing_order, vec!["gen", "sum"], "batching={enabled}");
                let out = &result.functions["sum"][0].outputs[0];
                assert!(
                    out.contains(&format!("{tag}-sum-n2")),
                    "batching={enabled}: run {tag} contaminated: {out}"
                );
            }
        }
    }

    #[test]
    fn per_resource_admission_limit_is_enforced() {
        let b = chain_bed(Arc::new(RealClock::new()));
        b.faas.set_engine_limits(16, 1);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        {
            let (live, peak) = (Arc::clone(&live), Arc::clone(&peak));
            b.executor.register("img/busy", move |_: &[u8]| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(20));
                live.fetch_sub(1, Ordering::SeqCst);
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        // A single-function app pinned to one Pi.
        let yaml = "\
application: busy
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
";
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![b.iot[0]]);
        b.faas.configure_application(yaml, &data).unwrap();
        b.faas.deploy_function("busy", "f", &FunctionPackage { code: "img/busy".into() }).unwrap();
        let ids: Vec<RunId> = (0..5)
            .map(|_| b.faas.submit_workflow("busy", &HashMap::new()).unwrap())
            .collect();
        for id in ids {
            b.faas.wait_workflow(id, 30.0).unwrap();
        }
        assert_eq!(
            peak.load(Ordering::SeqCst),
            1,
            "admission limit of 1 must serialize instances on the resource"
        );
    }

    #[test]
    fn events_fire_and_allow_midrun_rescheduling() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let nodes = Arc::new(Mutex::new(Vec::<String>::new()));
        let runs_done = Arc::new(AtomicUsize::new(0));
        // Mid-run reaction: when `gen` completes, migrate `sum` to the other
        // edge before it fires (the reschedule_function hook point).
        let target = b.edges[1];
        b.faas
            .resource(target)
            .unwrap()
            .handle
            .deploy("chain.sum", "img/sum", 128 << 20, 0, &[])
            .unwrap();
        {
            let nodes = Arc::clone(&nodes);
            let runs_done = Arc::clone(&runs_done);
            b.faas.on_engine_event(move |faas, ev| match ev {
                EngineEvent::NodeCompleted { function, .. } => {
                    nodes.lock().unwrap().push(function.clone());
                    if function == "gen" {
                        faas.set_candidates("chain", "sum", vec![target]).unwrap();
                    }
                }
                EngineEvent::RunCompleted { ok, .. } => {
                    assert!(ok);
                    runs_done.fetch_add(1, Ordering::SeqCst);
                }
                EngineEvent::DeadlineMissed { .. } => unreachable!("no deadlines set"),
                EngineEvent::ResourceDead { .. } | EngineEvent::ResourceRecovered { .. } => {
                    unreachable!("no liveness transitions in this test")
                }
            });
        }
        let run = b.faas.submit_workflow("chain", &entry_for("ev")).unwrap();
        let result = b.faas.wait_workflow(run, 10.0).unwrap();
        assert_eq!(result.functions["sum"][0].resource, target, "sum moved mid-run");
        assert_eq!(*nodes.lock().unwrap(), vec!["gen", "sum"]);
        assert_eq!(runs_done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn failed_stage_surfaces_the_handler_error() {
        let b = chain_bed(Arc::new(RealClock::new()));
        b.executor.register("img/sum", |_: &[u8]| anyhow::bail!("sum exploded"));
        let bad = b.faas.submit_workflow("chain", &entry_for("bad")).unwrap();
        let err = b.faas.wait_workflow(bad, 10.0).unwrap_err().to_string();
        assert!(err.contains("sum exploded"), "{err}");
    }

    #[test]
    fn unknown_app_and_unknown_run_error() {
        let b = chain_bed(Arc::new(RealClock::new()));
        assert!(b.faas.submit_workflow("ghost", &HashMap::new()).is_err());
        assert_eq!(
            b.faas.wait_workflow(999_999, 0.05).unwrap_err(),
            WaitError::UnknownRun { run: 999_999 }
        );
        assert!(b.faas.run_status(999_999).is_none());
    }

    #[test]
    fn shard_knob_clamps_and_stays_correct_at_every_count() {
        for shards in [0usize, 1, 4, 999] {
            let b = chain_bed(Arc::new(RealClock::new()));
            b.faas.set_engine_shards(shards);
            assert_eq!(b.faas.engine_shards(), shards.clamp(1, ENGINE_SHARDS));
            let run = b.faas.submit_workflow("chain", &entry_for("s0")).unwrap();
            let result = b.faas.wait_workflow(run, 10.0).unwrap();
            assert_eq!(result.firing_order, vec!["gen", "sum"], "shards={shards}");
            assert!(result.functions["sum"][0].outputs[0].contains("s0-sum-n2"));
        }
    }

    #[test]
    fn engine_stats_track_dispatches() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let run = b.faas.submit_workflow("chain", &entry_for("st")).unwrap();
        b.faas.wait_workflow(run, 10.0).unwrap();
        let stats = b.faas.engine_stats();
        assert_eq!(stats.shards, ENGINE_SHARDS);
        assert_eq!(stats.pending_runs, 0, "run retired");
        assert_eq!(stats.queued_instances, 0, "queues drained");
        assert_eq!(stats.instances_dispatched, 3, "2 gen + 1 sum");
        assert!(stats.batch_dispatches >= 1 && stats.batch_dispatches <= 3);
    }

    // ------------------------------------------------- queue-order units --

    fn inst(run: RunId, rid: ResourceId, class: Priority, deadline_ns: u64) -> Task {
        Task::Instance(InstanceTask {
            run,
            app: "a".into(),
            function: "f".into(),
            instance: 0,
            resource: rid,
            class,
            deadline_ns,
            envelope: Bytes::new(),
            attempt: 0,
            retried: false,
        })
    }

    /// Push straight into one shard's ready queue, with the same key
    /// assignment and counter bookkeeping as `enqueue`.
    fn push(eng: &EngineCore, st: &mut DispatchState, t: Task) {
        let seq = eng.next_seq.fetch_add(1, Ordering::SeqCst);
        let key = QKey { class: t.class().rank(), deadline_ns: t.deadline_ns(), seq };
        match &t {
            Task::Instance(_) => {
                eng.queued_instances.fetch_add(1, Ordering::SeqCst);
            }
            Task::Job { .. } => {
                eng.queued_jobs.fetch_add(1, Ordering::SeqCst);
            }
        }
        if t.class() == Priority::Batch {
            eng.queued_batch_class.fetch_add(1, Ordering::SeqCst);
        }
        st.ready.insert(key, t);
    }

    /// Pop one task and release its admission slot (simulates instant
    /// completion so admission never interferes with order checks).
    fn pop_run(eng: &EngineCore, st: &mut DispatchState) -> RunId {
        match eng.pop_task(st, 8) {
            Some(Task::Instance(t)) => {
                if let Some(n) = st.in_use.get_mut(&t.resource) {
                    *n = n.saturating_sub(1);
                }
                t.run
            }
            _ => panic!("expected an instance"),
        }
    }

    #[test]
    fn pop_orders_by_class_then_deadline_then_submission() {
        let eng = EngineCore::new();
        let mut st = eng.dispatch[0].state.lock().unwrap();
        // Submission order: batch, interactive (late deadline), realtime,
        // interactive (early deadline), interactive (no deadline).
        push(&eng, &mut st, inst(0, 0, Priority::Batch, u64::MAX));
        push(&eng, &mut st, inst(1, 1, Priority::Interactive, 2_000_000_000));
        push(&eng, &mut st, inst(2, 2, Priority::Realtime, u64::MAX));
        push(&eng, &mut st, inst(3, 3, Priority::Interactive, 1_000_000_000));
        push(&eng, &mut st, inst(4, 4, Priority::Interactive, u64::MAX));
        // Class first (realtime), then EDF within interactive (run 3 before
        // run 1), no-deadline interactive last of its class, batch last.
        assert_eq!(pop_run(&eng, &mut st), 2, "realtime jumps the queue");
        assert_eq!(pop_run(&eng, &mut st), 3, "earliest deadline first");
        assert_eq!(pop_run(&eng, &mut st), 1);
        assert_eq!(pop_run(&eng, &mut st), 4, "no deadline sorts after deadlines");
        assert_eq!(pop_run(&eng, &mut st), 0, "batch drains last");
        assert!(eng.pop_task(&mut st, 8).is_none());
        assert_eq!(eng.queued_instances.load(Ordering::SeqCst), 0, "counters settled");
        assert_eq!(eng.queued_batch_class.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn same_key_fields_fall_back_to_submission_order() {
        let eng = EngineCore::new();
        let mut st = eng.dispatch[0].state.lock().unwrap();
        for run in 0..5 {
            push(&eng, &mut st, inst(run, run as ResourceId, Priority::Interactive, u64::MAX));
        }
        for run in 0..5 {
            assert_eq!(pop_run(&eng, &mut st), run, "FIFO within identical class/deadline");
        }
    }

    #[test]
    fn aging_guard_dispatches_batch_after_the_limit() {
        let eng = EngineCore::new();
        let mut st = eng.dispatch[0].state.lock().unwrap();
        // One batch task waits while a steady interactive stream arrives.
        push(&eng, &mut st, inst(1000, 99, Priority::Batch, u64::MAX));
        for i in 0..(2 * BATCH_AGE_LIMIT) {
            push(&eng, &mut st, inst(i, i as ResourceId, Priority::Interactive, u64::MAX));
        }
        let mut pops_before_batch = 0u64;
        loop {
            let run = pop_run(&eng, &mut st);
            if run == 1000 {
                break;
            }
            pops_before_batch += 1;
            // Keep the stream topped up so strict priority alone would
            // starve the batch task forever.
            push(
                &eng,
                &mut st,
                inst(5000 + pops_before_batch, 7, Priority::Interactive, u64::MAX),
            );
            assert!(
                pops_before_batch <= BATCH_AGE_LIMIT,
                "batch task starved past the aging limit"
            );
        }
        assert_eq!(
            pops_before_batch, BATCH_AGE_LIMIT,
            "batch dispatches exactly at the aging threshold"
        );
    }

    #[test]
    fn flags_order_by_class_and_upgrade_in_place() {
        // Flag three shards Batch-first, then upgrade one to Realtime: the
        // coordination set must hand out the Realtime shard first, and the
        // upgrade must replace (not duplicate) the old entry.
        let eng = EngineCore::new();
        {
            let mut st = eng.dispatch[3].state.lock().unwrap();
            // No free workers: the flag asks for a spawn (the counter is
            // reserved; no thread is actually started in this unit test).
            assert!(eng.flag_shard_locked(&mut st, 3, Priority::Batch.rank()));
        }
        {
            let mut st = eng.dispatch[5].state.lock().unwrap();
            eng.flag_shard_locked(&mut st, 5, Priority::Batch.rank());
        }
        {
            let mut st = eng.dispatch[5].state.lock().unwrap();
            assert!(
                !eng.flag_shard_locked(&mut st, 5, Priority::Realtime.rank()),
                "an upgrade re-keys the existing flag, it does not spawn"
            );
        }
        let c = eng.coord.state.lock().unwrap();
        assert_eq!(c.flags.len(), 2, "upgrade replaced the old flag");
        let first = c.flags.iter().next().copied().unwrap();
        assert_eq!(first.2, 5, "the realtime-flagged shard is served first");
        assert_eq!(first.0, Priority::Realtime.rank());
    }

    #[test]
    fn deadline_exceeded_run_fails_without_executing() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let missed = Arc::new(AtomicUsize::new(0));
        {
            let missed = Arc::clone(&missed);
            b.faas.on_engine_event(move |_, ev| {
                if let EngineEvent::DeadlineMissed { deadline_s, late_by, .. } = ev {
                    assert_eq!(*deadline_s, 0.0);
                    assert!(*late_by >= 0.0);
                    missed.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        // A deadline of zero is already past at first dispatch.
        let run = b
            .faas
            .submit_workflow_qos(
                "chain",
                &entry_for("dl"),
                QoS::class(Priority::Interactive).with_deadline(0.0),
            )
            .unwrap();
        let err = b.faas.wait_workflow(run, 10.0).unwrap_err();
        assert_eq!(err, WaitError::DeadlineExceeded { run });
        assert_eq!(missed.load(Ordering::SeqCst), 1, "DeadlineMissed fires once");
    }

    #[test]
    fn backpressure_saturates_and_sheds_batch_first() {
        let b = chain_bed(Arc::new(RealClock::new()));
        // One worker, one slot, no batching: the first popped instance
        // occupies the engine while the gate holds (a drain would pull the
        // other runs' iot-0 instances into its batch and make them
        // ineligible for shedding), so queue state is deterministic.
        b.faas.set_engine_limits(1, 1);
        b.faas.set_batching(false);
        b.faas.set_backpressure(3, 1024);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        {
            let gate = Arc::clone(&gate);
            b.executor.register("img/gen", move |_: &[u8]| {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        b.executor.register("img/sum", |_: &[u8]| Ok(br#"{"outputs":[]}"#.to_vec()));
        let batch_qos = QoS::class(Priority::Batch);
        let b0 = b.faas.submit_workflow_qos("chain", &entry_for("b0"), batch_qos).unwrap();
        let b1 = b.faas.submit_workflow_qos("chain", &entry_for("b1"), batch_qos).unwrap();
        let b2 = b.faas.submit_workflow_qos("chain", &entry_for("b2"), batch_qos).unwrap();
        // The lone worker must have popped b0's first instance before the
        // shed scan runs, or b0 is fully queued and becomes sheddable.
        while b.faas.engine_stats().instances_dispatched == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        // 3 pending batch runs: a 4th batch submission is refused...
        match b.faas.submit_workflow_qos("chain", &entry_for("b3"), batch_qos) {
            Err(EngineError::Saturated { pending_runs, max_pending_runs, .. }) => {
                assert_eq!((pending_runs, max_pending_runs), (3, 3));
            }
            other => panic!("expected Saturated, got {other:?}"),
        }
        // ...but an interactive submission sheds the newest fully-queued
        // batch run (b2; b0 has an instance executing behind the gate).
        let rt = b
            .faas
            .submit_workflow_qos("chain", &entry_for("i0"), QoS::default())
            .unwrap();
        let err = b.faas.wait_workflow(b2, 10.0).unwrap_err();
        match err {
            WaitError::RunFailed { run, message } => {
                assert_eq!(run, b2);
                assert!(message.contains("shed under backpressure"), "{message}");
            }
            other => panic!("expected shed failure, got {other:?}"),
        }
        // Release the gate: the survivors all complete.
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        for id in [b0, b1, rt] {
            b.faas.wait_workflow(id, 30.0).unwrap();
        }
    }

    #[test]
    fn admission_bound_scales_with_the_schedulable_fleet() {
        let b = chain_bed(Arc::new(RealClock::new()));
        b.faas.set_backpressure(4, 1024);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        {
            let gate = Arc::clone(&gate);
            b.executor.register("img/gen", move |_: &[u8]| {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        b.executor.register("img/sum", |_: &[u8]| Ok(br#"{"outputs":[]}"#.to_vec()));
        // Half the fleet unschedulable: the bound of 4 scales to 4*2/4 = 2.
        b.faas.engine.set_fleet(4, 2);
        let batch_qos = QoS::class(Priority::Batch);
        let b0 = b.faas.submit_workflow_qos("chain", &entry_for("b0"), batch_qos).unwrap();
        let b1 = b.faas.submit_workflow_qos("chain", &entry_for("b1"), batch_qos).unwrap();
        match b.faas.submit_workflow_qos("chain", &entry_for("b2"), batch_qos) {
            Err(EngineError::Saturated { pending_runs, max_pending_runs, .. }) => {
                assert_eq!((pending_runs, max_pending_runs), (2, 2));
            }
            other => panic!("expected lease-scaled Saturated, got {other:?}"),
        }
        // Full fleet again: the same submission is admitted.
        b.faas.engine.set_fleet(4, 4);
        let b2 = b.faas.submit_workflow_qos("chain", &entry_for("b2"), batch_qos).unwrap();
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        for id in [b0, b1, b2] {
            b.faas.wait_workflow(id, 30.0).unwrap();
        }
    }

    #[test]
    fn batch_window_coalesces_under_light_load() {
        // Four single-stage runs on one unsaturated resource: without a
        // window each dispatches alone; with one, the slot holder fills a
        // batch of four. Virtual clock — the window loop must terminate on
        // its wall-bounded wait even though now() never advances.
        for (window_s, want_dispatches) in [(0.0f64, 4u64), (0.02, 1u64)] {
            let b = paper_testbed(Arc::new(VirtualClock::new()));
            b.executor.register("img/solo", |_: &[u8]| Ok(br#"{"outputs":[]}"#.to_vec()));
            let yaml = "\
application: solo
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
";
            let mut data = HashMap::new();
            data.insert("f".to_string(), vec![b.iot[0]]);
            b.faas.configure_application(yaml, &data).unwrap();
            b.faas
                .deploy_function("solo", "f", &FunctionPackage { code: "img/solo".into() })
                .unwrap();
            // 1 worker; 2 slots = light load (the non-window path must not
            // coalesce below the admission limit).
            b.faas.set_engine_limits(1, 2);
            b.faas.set_batch_window(window_s);
            assert!((b.faas.batch_window() - window_s).abs() < 1e-9);
            // Park the lone worker on a gated job so all four runs queue
            // before any dispatch decision — deterministic under any clock.
            let gate = Arc::new((Mutex::new(false), Condvar::new()));
            {
                let gate = Arc::clone(&gate);
                b.faas.spawn_job(move |_| {
                    let (lock, cv) = &*gate;
                    let mut open = lock.lock().unwrap();
                    while !*open {
                        open = cv.wait(open).unwrap();
                    }
                });
            }
            let ids: Vec<RunId> = (0..4)
                .map(|_| b.faas.submit_workflow("solo", &HashMap::new()).unwrap())
                .collect();
            {
                let (lock, cv) = &*gate;
                *lock.lock().unwrap() = true;
                cv.notify_all();
            }
            for id in ids {
                b.faas.wait_workflow(id, 30.0).unwrap();
            }
            let stats = b.faas.engine_stats();
            assert_eq!(stats.instances_dispatched, 4, "window={window_s}");
            assert_eq!(
                stats.batch_dispatches, want_dispatches,
                "window={window_s}: the window must decide whether the four \
                 instances coalesce"
            );
        }
    }
}
